"""Serving subsystem tests: batch bucketing, hot-swap atomicity, drift
monitoring, sidecar validation, and the re-federation loop (ISSUE 6).

Unit layers use tiny hand-rolled scorers so nothing here trains; the
integration test at the bottom runs the full train -> serve -> drift ->
re-federate loop in-process on a miniature spec, and the CLI smokes are
gated behind ``REPRO_SMOKE=1`` like the example suite.
"""
import os
import pathlib
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (DataSpec, ExperimentSession, ExperimentSpec,
                       WorldSpec)
from repro.api import session as session_mod
from repro.configs import anomaly_mlp, registry
from repro.core import scenario as scenario_mod
from repro.models import api as model_api
from repro.serve import (DriftMonitor, ModelSlot, Refederator, ServeEngine,
                         ServeModelError, StaleCheckpointError)

ROOT = pathlib.Path(__file__).resolve().parents[1]
CFG = anomaly_mlp.SMOKE
SMOKE = bool(os.environ.get("REPRO_SMOKE"))


def _params(seed=0):
    return model_api.init_params(jax.random.PRNGKey(seed), CFG)


def _flows(seed, n):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, CFG.num_features)).astype(np.float32)


# ---------------------------------------------------------------------
# engine: bucketing + padding + accounting
# ---------------------------------------------------------------------
class TestBuckets:
    def test_bucket_for_rounds_up_to_power_of_two(self):
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=64)
        assert [eng.bucket_for(n) for n in (1, 2, 3, 5, 8, 9, 33, 64)] \
            == [1, 2, 4, 8, 8, 16, 64, 64]
        with pytest.raises(ValueError):
            eng.bucket_for(0)
        with pytest.raises(ValueError):
            eng.bucket_for(65)

    def test_max_batch_must_be_power_of_two(self):
        with pytest.raises(ValueError, match="power of two"):
            ServeEngine(ModelSlot(_params()), CFG, max_batch=48)

    def test_padded_tail_matches_unpadded_scores(self):
        """A 5-request batch runs in the 8-bucket; the pad rows must not
        leak into responses and the real rows must score exactly as a
        tight batch would."""
        params = _params()
        eng = ServeEngine(ModelSlot(params), CFG, max_batch=8)
        X = _flows(3, 5)
        eng.submit_many(X)
        out = eng.pump()
        assert [r.request_id for r in out] == [0, 1, 2, 3, 4]
        from repro.models import mlp_detector
        direct = np.asarray(mlp_detector.predict(
            params, jnp.asarray(X), CFG))
        got = np.stack([r.probs for r in out])
        np.testing.assert_allclose(got, direct, rtol=1e-5, atol=1e-6)
        for r in out:
            np.testing.assert_allclose(
                r.score, 1.0 - r.probs[0], rtol=1e-6)

    def test_stream_splits_into_buckets_and_counts(self):
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=32)
        eng.submit_many(_flows(0, 70))          # 32 + 32 + 6-in-8
        out = eng.drain()
        assert len(out) == 70
        stats = eng.shutdown()
        assert stats.submitted == stats.served == 70
        assert stats.dropped == 0 and stats.errors == 0
        assert set(stats.by_bucket) == {32, 8}
        assert stats.by_bucket[32]["rows"] == 64
        assert stats.by_bucket[8]["rows"] == 6
        assert stats.p99_ms >= stats.p50_ms >= 0.0

    def test_reset_stats_preserves_versions_and_ids(self):
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=16)
        eng.submit_many(_flows(9, 10))
        with pytest.raises(RuntimeError, match="drain first"):
            eng.reset_stats()
        eng.drain()
        eng.reset_stats()
        assert eng.stats().submitted == 0
        rid = eng.submit(_flows(9, 1)[0])
        assert rid == 10                     # id sequence not reset
        eng.drain()
        assert eng.stats().served == 1
        assert eng.versions_served == [0]    # version history kept

    def test_submit_validates_shape(self):
        eng = ServeEngine(ModelSlot(_params()), CFG)
        with pytest.raises(ValueError, match="shape"):
            eng.submit(np.zeros(CFG.num_features + 1, np.float32))

    def test_shutdown_drains_then_refuses(self):
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=16)
        eng.submit_many(_flows(1, 21))
        stats = eng.shutdown()
        assert stats.served == 21 and stats.pending == 0
        assert stats.dropped == 0
        with pytest.raises(RuntimeError, match="shut down"):
            eng.submit(np.zeros(CFG.num_features, np.float32))


# ---------------------------------------------------------------------
# swap: double-buffered slot semantics
# ---------------------------------------------------------------------
class TestModelSlot:
    def test_flip_happens_at_acquire_and_is_versioned(self):
        slot = ModelSlot(_params(0), model="m", round_idx=2)
        p0, m0 = slot.acquire()
        assert m0.version == 0 and m0.round_idx == 2
        slot.publish(_params(1), round_idx=5)
        assert slot.version == 0              # not flipped yet
        assert slot.staged_version == 1
        _p1, m1 = slot.acquire()
        assert m1.version == 1 and m1.round_idx == 5
        assert slot.swaps == 1 and slot.staged_version is None

    def test_republish_before_flip_last_writer_wins(self):
        slot = ModelSlot(_params())
        slot.publish(_params(1))
        meta2 = slot.publish(_params(2))
        assert meta2.version == 2
        _p, m = slot.acquire()
        assert m.version == 2 and slot.swaps == 1   # one flip, newest wins

    def test_swap_atomicity_under_churn(self):
        """Background publishes racing a scoring loop: every batch sees a
        single consistent version, versions are monotone, and no request
        is dropped."""
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=16)
        stop = threading.Event()

        def publisher():
            k = 1
            while not stop.is_set():
                eng.slot.publish(_params(k))
                k += 1

        t = threading.Thread(target=publisher, daemon=True)
        t.start()
        seen = []
        try:
            for chunk in range(30):
                eng.submit_many(_flows(chunk, 13))
                for r in eng.drain():
                    seen.append((r.request_id, r.model_version))
        finally:
            stop.set()
            t.join(5)
        stats = eng.shutdown()
        assert stats.served == stats.submitted == 30 * 13
        assert stats.dropped == 0 and stats.errors == 0
        versions = [v for _rid, v in sorted(seen)]
        assert versions == sorted(versions), "versions must be monotone"
        assert len(eng.versions_served) >= 2, "churn never flipped a model"


# ---------------------------------------------------------------------
# scenario drift-stat helpers + monitor policy
# ---------------------------------------------------------------------
class TestDriftStats:
    def test_reference_snapshot_is_exact_moments(self):
        x = _flows(0, 512)
        s = np.abs(x[:, 0])
        ref = scenario_mod.reference_snapshot(jnp.asarray(x),
                                              jnp.asarray(s))
        np.testing.assert_allclose(np.asarray(ref.feat_mean), x.mean(0),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(ref.feat_var), x.var(0),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(ref.score_mean), s.mean(),
                                   atol=1e-5)

    def test_update_is_masked_and_chunking_snaps_first_batch(self):
        x = _flows(1, 64)
        s = x[:, 0]
        stats = scenario_mod.init_drift_stats(CFG.num_features)
        # pad rows carry garbage; the mask must exclude them
        xpad = np.concatenate([x, 1e6 * np.ones_like(x[:32])])
        spad = np.concatenate([s, 1e6 * np.ones_like(s[:32])])
        mask = np.concatenate([np.ones(64), np.zeros(32)]).astype(
            np.float32)
        upd = scenario_mod.drift_stats_update(
            stats, jnp.asarray(xpad), jnp.asarray(spad),
            mask=jnp.asarray(mask))
        np.testing.assert_allclose(np.asarray(upd.feat_mean), x.mean(0),
                                   atol=1e-4)
        assert float(upd.count) == 64.0

    def test_statistic_zero_on_reference_and_grows_with_shift(self):
        x = _flows(2, 1024)
        s = np.abs(x[:, 1])
        ref = scenario_mod.reference_snapshot(jnp.asarray(x),
                                              jnp.asarray(s))
        same = scenario_mod.drift_stats_update(
            scenario_mod.init_drift_stats(CFG.num_features),
            jnp.asarray(x), jnp.asarray(s))
        base = float(scenario_mod.drift_statistic(same, ref))
        assert base < 0.05
        shifted = scenario_mod.drift_stats_update(
            scenario_mod.init_drift_stats(CFG.num_features),
            jnp.asarray(x + 2.0), jnp.asarray(s))
        far = float(scenario_mod.drift_statistic(shifted, ref))
        assert far > 1.0 > base


class TestDriftMonitor:
    def _monitor(self, **kw):
        x = _flows(0, 512)
        return DriftMonitor.from_sample(x, np.abs(x[:, 0]),
                                        threshold=0.5, **kw)

    def test_triggers_after_exactly_patience_windows(self):
        mon = self._monitor(patience=3)
        fired = []
        for w in range(5):
            x = _flows(10 + w, 128) + 3.0       # well over threshold
            st, stat = mon.step(mon.state, mon.reference,
                                jnp.asarray(x),
                                jnp.asarray(np.abs(x[:, 0])))
            fired.append(mon.observe(st, stat))
        assert fired == [False, False, True, False, False]
        assert mon.triggered and mon.trigger_count == 1

    def test_clean_windows_reset_the_patience_counter(self):
        mon = self._monitor(patience=2)
        for w, shift in enumerate([3.0, 0.0, 3.0, 0.0, 3.0]):
            x = _flows(20 + w, 256) + shift
            st, stat = mon.step(mon.state, mon.reference,
                                jnp.asarray(x),
                                jnp.asarray(np.abs(x[:, 0])))
            assert not mon.observe(st, stat)
        assert not mon.triggered

    def test_rearm_adopt_current_clears_and_renormalizes(self):
        mon = self._monitor(patience=1)
        x = _flows(30, 512) + 3.0
        scores = np.abs(x[:, 0])
        st, stat = mon.step(mon.state, mon.reference, jnp.asarray(x),
                            jnp.asarray(scores))
        assert mon.observe(st, stat)
        mon.rearm(adopt_current=True)
        assert not mon.triggered
        # the shifted distribution is now the reference -> quiet again
        x2 = _flows(31, 512) + 3.0
        st2, stat2 = mon.step(mon.state, mon.reference, jnp.asarray(x2),
                              jnp.asarray(np.abs(x2[:, 0])))
        assert float(stat2) < 0.2
        assert not mon.observe(st2, stat2)

    def test_rearm_is_visible_to_compiled_buckets(self):
        """The engine jits one scorer per bucket; a rearm AFTER those
        compiles must still change the statistic (reference is an
        argument, not a trace constant)."""
        params = _params()
        x = _flows(40, 256)
        from repro.models import mlp_detector
        scores = 1.0 - np.asarray(mlp_detector.predict(
            params, jnp.asarray(x), CFG))[:, 0]
        mon = DriftMonitor.from_sample(x, scores, threshold=0.5,
                                       patience=1)
        eng = ServeEngine(ModelSlot(params), CFG, max_batch=32,
                          monitor=mon)
        eng.submit_many(_flows(41, 32) + 3.0)   # compiles the 32-bucket
        eng.drain()
        hot = mon.statistic
        assert hot > 0.5
        mon.rearm(adopt_current=True)           # shifted = new normal
        eng.submit_many(_flows(42, 32) + 3.0)   # same compiled bucket
        eng.drain()
        assert mon.statistic < 0.5 < hot

    def test_engine_on_trigger_fires_once_per_arming(self):
        x = _flows(50, 256)
        mon = DriftMonitor.from_sample(x, np.abs(x[:, 0]),
                                       threshold=0.5, patience=2)
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=64,
                          monitor=mon,
                          score_fn=lambda p, xb: jnp.stack(
                              [1.0 - jnp.abs(xb[:, 0]),
                               jnp.abs(xb[:, 0])], axis=1))
        hits = []
        eng.on_trigger = lambda: hits.append(mon.statistic)
        for w in range(5):
            eng.submit_many(_flows(60 + w, 64) + 4.0)
            eng.drain()
        assert len(hits) == 1 and mon.trigger_count == 1


# ---------------------------------------------------------------------
# checkpoint sidecar + publish_checkpoint validation
# ---------------------------------------------------------------------
SMALL = dict(model=CFG,
             data=DataSpec(n_samples=512, eval_samples=128),
             world=WorldSpec(num_clients=3, profile="uniform"),
             strategy="ours",
             strategy_kwargs=dict(batch_size=32, lr=3e-2, local_epochs=1),
             rounds=2, seed=0)


@pytest.fixture(scope="module")
def trained_ckpt(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("serve_ckpt") / "run.ckpt")
    session = ExperimentSession.open(ExperimentSpec(**SMALL))
    session.run()
    session.checkpoint(path)
    return path, session.result().params


class TestCheckpointSidecar:
    def test_checkpoint_writes_sidecar(self, trained_ckpt):
        path, _ = trained_ckpt
        meta = session_mod.read_sidecar(path)
        assert meta["model"] == CFG.name
        assert meta["rounds_done"] == 2
        assert meta["fingerprint"]
        assert os.path.exists(session_mod.sidecar_path(path))

    def test_read_sidecar_missing_is_pointed(self, tmp_path):
        with pytest.raises(FileNotFoundError, match="sidecar"):
            session_mod.read_sidecar(str(tmp_path / "nope.ckpt"))

    def test_publish_checkpoint_flips_in(self, trained_ckpt):
        path, params = trained_ckpt
        slot = ModelSlot(_params(), model=CFG.name, round_idx=0)
        meta = slot.publish_checkpoint(path)
        assert meta.version == 1 and meta.round_idx == 2
        assert meta.source == path
        got, m = slot.acquire()
        assert m.version == 1
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(got)[0]),
            np.asarray(jax.tree.leaves(params)[0]))

    def test_rejects_model_mismatch(self, trained_ckpt):
        path, _ = trained_ckpt
        slot = ModelSlot(_params(), model="other-arch")
        with pytest.raises(ServeModelError, match="different architecture"):
            slot.publish_checkpoint(path)

    def test_rejects_stale_round_counter(self, trained_ckpt):
        path, _ = trained_ckpt
        slot = ModelSlot(_params(), model=CFG.name, round_idx=10)
        with pytest.raises(StaleCheckpointError, match="round"):
            slot.publish_checkpoint(path)
        # explicit rollback and round_base offsets both unblock it
        assert slot.publish_checkpoint(path, allow_stale=True).version >= 1
        slot2 = ModelSlot(_params(), model=CFG.name, round_idx=10)
        meta = slot2.publish_checkpoint(path, round_base=10)
        assert meta.round_idx == 12


# ---------------------------------------------------------------------
# the full loop, in process (miniature)
# ---------------------------------------------------------------------
class TestContinuousLoop:
    def test_trigger_refederates_and_recovers(self, tmp_path):
        from repro.data import synthetic
        from repro.models import mlp_detector

        def traffic(seed, n, shift):
            X, y = synthetic.make_unsw_like(seed, n, CFG.num_features,
                                            CFG.num_classes)
            return X + shift, y

        def spec(shift, seed):
            return ExperimentSpec(**{
                **SMALL, "seed": seed,
                "data": DataSpec(n_samples=512, eval_samples=128,
                                 factory=lambda s, n: traffic(s, n,
                                                              shift))})

        session = ExperimentSession.open(spec(0.0, 0))
        session.run()
        params = session.result().params
        slot = ModelSlot(params, model=CFG.name, round_idx=2)
        Xr, _ = traffic(7, 512, 0.0)
        sref = 1.0 - np.asarray(mlp_detector.predict(
            params, jnp.asarray(Xr), CFG))[:, 0]
        mon = DriftMonitor.from_sample(Xr, sref, threshold=0.5,
                                       patience=2)
        refed = Refederator(slot, lambda k: spec(2.0, 100 + k),
                            ckpt_dir=str(tmp_path), monitor=mon,
                            background=False)       # deterministic test
        eng = ServeEngine(slot, CFG, max_batch=64, monitor=mon)
        eng.on_trigger = refed.fire

        for w in range(6):                           # drifted traffic
            X, _y = traffic(200 + w, 64, 2.0)
            eng.submit_many(X)
            eng.drain()
            if refed.completed:
                break
        if refed.last_error is not None:
            raise refed.last_error
        assert mon.trigger_count == 1
        assert refed.completed == 1
        assert refed.last_checkpoint and \
            os.path.exists(session_mod.sidecar_path(refed.last_checkpoint))
        # the loop is proven; the refreshed model re-shapes the score
        # distribution, so disarm auto-fire for the post-swap check
        # (the demo re-references the monitor instead)
        eng.on_trigger = None
        X, _y = traffic(300, 64, 2.0)               # post-swap window
        eng.submit_many(X)
        out = eng.drain()
        assert {r.model_version for r in out} == {1}
        assert not mon.triggered                     # re-armed
        stats = eng.shutdown()
        assert stats.dropped == 0 and stats.errors == 0
        assert slot.swaps >= 1


# ---------------------------------------------------------------------
# registry surface
# ---------------------------------------------------------------------
def test_registry_list_archs_is_public_and_sorted():
    archs = registry.list_archs()
    assert archs == sorted(archs)
    assert "anomaly-mlp" in archs
    for a in archs:
        assert registry.get_config(a, smoke=True) is not None


# ---------------------------------------------------------------------
# CLI smokes (subprocess, REPRO_SMOKE=1 only — same gate as examples)
# ---------------------------------------------------------------------
@pytest.mark.skipif(not SMOKE, reason="REPRO_SMOKE=1 subprocess smokes")
@pytest.mark.parametrize("argv", [
    ["--arch", "anomaly-mlp", "--batch", "32", "--requests", "96"],
    ["--arch", "qwen2-1.5b", "--smoke", "--prompt-len", "8",
     "--decode-steps", "2", "--batch", "2"],
])
def test_serve_cli_smoke(argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve"] + argv,
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"serve CLI failed\n--- stdout ---\n{proc.stdout[-2000:]}"
        f"\n--- stderr ---\n{proc.stderr[-2000:]}")
    assert proc.stdout.strip()
