"""repro.api: spec validation, strategy-registry round-trip, sim/spmd
result-schema parity, and seeded reproducibility."""
import dataclasses

import numpy as np
import pytest

from repro.api import (ROUND_FIELDS, CommModel, DataSpec, ExperimentSpec,
                       STRATEGY_REGISTRY, ScheduleSpec, SpecError,
                       StrategyConfig, WorldSpec, get_strategy,
                       list_strategies, register_strategy, run_experiment)

SMALL = dict(model="anomaly-mlp-smoke",
             data=DataSpec(n_samples=1200, eval_samples=300),
             world=WorldSpec(num_clients=4, profile="uniform"),
             rounds=2, seed=0)


def _spec(**kw):
    return ExperimentSpec(**{**SMALL, **kw})


def _degenerate_strategy(bs=32):
    # one local step (max_samples == batch) -> sim == spmd exactly
    return StrategyConfig(mode="sync", theta=None, selection=False,
                          dynamic_batch=False, checkpointing=False,
                          batch_size=bs, lr=3e-2, local_epochs=1,
                          max_samples_per_round=bs)


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------

def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="unknown engine"):
        _spec(engine="ray").validate()


def test_bad_rounds_rejected():
    with pytest.raises(ValueError, match="rounds"):
        _spec(rounds=0).validate()


def test_unknown_strategy_lists_registry():
    with pytest.raises(ValueError, match="fedavg"):
        _spec(strategy="no-such-strategy").validate()


def test_unknown_partition_rejected():
    with pytest.raises(ValueError, match="partition"):
        _spec(data=DataSpec(partition="zipf")).validate()


def test_spmd_rejects_async_and_dynamic_batch():
    # "ours" is async + dynamic_batch: both remain sim-only semantics
    with pytest.raises(ValueError, match="spmd"):
        _spec(engine="spmd", strategy="ours").validate()
    with pytest.raises(ValueError, match="dynamic_batch"):
        _spec(engine="spmd", strategy=get_strategy("fedavg").build(
            dynamic_batch=True)).validate()


def test_spmd_accepts_selection_and_dropout():
    """The device control plane handles selection, dropout and quantized
    updates as cohort masking — validate() must accept them now."""
    st = dataclasses.replace(_degenerate_strategy(), selection=True,
                             select_fraction=0.5, quantize_updates=True,
                             per_client_lr=True)
    _spec(engine="spmd", strategy=st,
          world=WorldSpec(num_clients=4, profile="uniform",
                          dropout_p=0.3)).validate()


def test_rounds_per_dispatch_validated():
    with pytest.raises(ValueError, match="rounds_per_dispatch"):
        _spec(rounds_per_dispatch=0).validate()
    with pytest.raises(ValueError, match="sim-engine"):
        _spec(engine="spmd", strategy=_degenerate_strategy(),
              rounds_per_dispatch=4).validate()
    with pytest.raises(ValueError, match="megastep"):
        _spec(rounds_per_dispatch=4, megastep=False).validate()
    _spec(rounds_per_dispatch=4).validate()


def test_lm_needs_iid_partition():
    spec = _spec(model="anomaly-mlp-smoke",
                 data=DataSpec(dataset="lm", partition="dirichlet"))
    with pytest.raises(ValueError, match="iid"):
        spec.build_world()


def test_spec_error_collects_every_violation():
    """validate() must report ALL problems at once — field, offending
    value and a hint each — not fail on the first bad field."""
    with pytest.raises(SpecError) as ei:
        _spec(engine="ray", rounds=0, eval_every=0,
              data=DataSpec(partition="zipf"),
              world=WorldSpec(num_clients=0, profile="exotic"),
              strategy="no-such-strategy").validate()
    err = ei.value
    fields = {i.field for i in err.issues}
    assert fields == {"engine", "rounds", "eval_every", "data.partition",
                      "world.num_clients", "world.profile", "strategy"}
    by_field = {i.field: i for i in err.issues}
    assert by_field["engine"].value == "ray"
    assert "sim" in by_field["engine"].hint
    # every issue is in the message, with its hint
    for issue in err.issues:
        assert issue.field in str(err)
    # SpecError stays a ValueError: legacy except-clauses keep working
    assert isinstance(err, ValueError)


def test_spec_error_includes_engine_knob_hints():
    with pytest.raises(SpecError) as ei:
        _spec(engine="spmd", strategy="ours",
              rounds_per_dispatch=4).validate()
    hints = " ".join(i.hint for i in ei.value.issues)
    assert "sim-engine" in hints          # rounds_per_dispatch hint
    assert "engine='sim'" in hints        # async-schedule hint


# ---------------------------------------------------------------------------
# ScheduleSpec: the explicit server-coordination axis
# ---------------------------------------------------------------------------

def test_schedule_defaults_to_strategy_mode_shim():
    """Legacy StrategyConfig.mode keeps working: the derived schedule
    mirrors mode/quorum/alpha0, and explicit ScheduleSpec equals it."""
    spec = _spec(strategy="ours")
    sched = spec.resolve_schedule()
    st = spec.resolve_strategy()
    assert sched.kind == st.mode == "async"
    assert sched.quorum == st.quorum and sched.alpha0 == st.alpha0
    explicit = _spec(strategy="ours",
                     schedule=ScheduleSpec.from_strategy(st))
    a = run_experiment(dataclasses.replace(spec, rounds=2))
    b = run_experiment(dataclasses.replace(explicit, rounds=2))
    assert a.records == b.records


def test_schedule_string_overrides_strategy_mode():
    # fedavg (a sync preset) under an async quorum — previously
    # unspellable without editing the preset
    spec = _spec(strategy="fedavg", schedule="async",
                 world=WorldSpec(num_clients=4, profile="heterogeneous"))
    assert spec.resolve_schedule().kind == "async"
    res = run_experiment(spec)
    assert res.final.idle_time == 0.0          # no sync barrier


def test_semi_async_requires_max_staleness():
    with pytest.raises(SpecError, match="max_staleness"):
        _spec(schedule=ScheduleSpec(kind="semi-async")).validate()
    with pytest.raises(SpecError, match="max_staleness"):
        _spec(schedule=ScheduleSpec(kind="sync",
                                    max_staleness=2)).validate()
    _spec(schedule=ScheduleSpec(kind="semi-async",
                                max_staleness=2)).validate()


def test_semi_async_drops_stale_updates():
    """Bounded staleness: a zero-staleness budget applies only the
    arrivals at/before the quorum rank, strictly fewer than plain async
    under straggler spread; trajectories stay deterministic."""
    world = WorldSpec(num_clients=6, profile="heterogeneous")
    base = _spec(strategy="ours",
                 strategy_kwargs=dict(batch_size=32, dynamic_batch=False),
                 world=world, rounds=3)
    plain = run_experiment(base)
    semi = run_experiment(dataclasses.replace(
        base, schedule=ScheduleSpec(kind="semi-async", quorum=0.5,
                                    max_staleness=0)))
    assert sum(r.updates_applied for r in semi.records) \
        < sum(r.updates_applied for r in plain.records)
    # round 0 (identical pre-aggregation state): dropped updates were
    # still transmitted, so the byte accounting matches exactly
    assert semi.records[0].bytes_sent == plain.records[0].bytes_sent
    assert semi.records[0].updates_applied \
        < plain.records[0].updates_applied


def test_spmd_rejects_async_schedule_axis():
    with pytest.raises(SpecError, match="schedule.kind"):
        _spec(engine="spmd", strategy=_degenerate_strategy(),
              schedule="async").validate()


# ---------------------------------------------------------------------------
# registry round-trip
# ---------------------------------------------------------------------------

def test_register_strategy_roundtrip():
    name = "_test-fedavg-fast"

    @register_strategy(name, "test-only preset")
    def fast(batch_size=32, **kw):
        return get_strategy("fedavg").build(batch_size=batch_size,
                                            lr=5e-2, **kw)

    try:
        assert name in list_strategies()
        res = run_experiment(_spec(strategy=name))
        assert res.strategy == name
        assert len(res.records) == SMALL["rounds"]
        assert res.final.accuracy > 0.0
    finally:
        del STRATEGY_REGISTRY[name]


def test_presets_all_instantiate():
    for name in list_strategies():
        cfg = get_strategy(name).build()
        assert isinstance(cfg, StrategyConfig), name


# ---------------------------------------------------------------------------
# engine parity (degenerate configuration) + schema
# ---------------------------------------------------------------------------

def test_sim_spmd_parity_degenerate():
    comm = CommModel(bandwidth=5e6, latency=0.0, t_sample=2e-3,
                     t_launch=0.25)
    spec = _spec(strategy=_degenerate_strategy(), comm=comm, rounds=3)
    sim = run_experiment(spec)
    spmd = run_experiment(dataclasses.replace(spec, engine="spmd"))
    assert sim.num_clients == spmd.num_clients
    assert sim.param_bytes == spmd.param_bytes
    for a, b in zip(sim.records, spmd.records):
        # exact: both engines account the same CommModel arithmetic,
        # including the 1-bit skip-beacon byte rule
        assert a.round == b.round
        assert a.sim_time == b.sim_time
        assert a.comm_time == b.comm_time
        assert a.idle_time == b.idle_time
        assert a.bytes_sent == b.bytes_sent
        # updates_applied is the COUNT of applied client updates on both
        # engines (the spmd runner used to record a 0/1 any-update flag)
        assert a.updates_applied == b.updates_applied == sim.num_clients
        assert a.accept_rate == b.accept_rate
        # fp32 trajectories coincide up to reduction order
        np.testing.assert_allclose(a.accuracy, b.accuracy, atol=1e-6)
        np.testing.assert_allclose(a.loss, b.loss, rtol=1e-4)


def test_round_record_schema():
    assert set(ROUND_FIELDS) >= {"accuracy", "sim_time", "bytes_sent",
                                 "idle_time", "accept_rate", "comm_time",
                                 "updates_applied", "loss", "round"}


# ---------------------------------------------------------------------------
# byte accounting: filtered clients pay the 1-bit skip beacon
# ---------------------------------------------------------------------------

def test_skip_beacon_charged_in_sim():
    comm = CommModel()
    # theta > 1 can never pass (alignment ratio <= 1): round 0 bootstraps
    # (no reference sign yet -> everyone sends), later rounds all skip
    spec = _spec(strategy=get_strategy("cmfl").build(batch_size=32,
                                                     theta=1.5),
                 comm=comm, rounds=3)
    res = run_experiment(spec)
    r0, r1, r2 = res.records
    C = res.num_clients
    assert r0.accept_rate == 1.0 and r1.accept_rate == 0.0
    assert r0.bytes_sent == C * res.param_bytes
    np.testing.assert_allclose(r1.bytes_sent - r0.bytes_sent,
                               C * comm.beacon_bytes)
    np.testing.assert_allclose(r2.bytes_sent - r1.bytes_sent,
                               C * comm.beacon_bytes)


# ---------------------------------------------------------------------------
# seeded reproducibility
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["sim", "spmd"])
def test_same_spec_same_records(engine):
    strategy = (_degenerate_strategy() if engine == "spmd"
                else get_strategy("ours").build(batch_size=32,
                                                dynamic_batch=False))
    spec = _spec(strategy=strategy, engine=engine,
                 world=WorldSpec(num_clients=4, profile="heterogeneous",
                                 dropout_p=0.0))
    a = run_experiment(spec)
    b = run_experiment(_spec(strategy=strategy, engine=engine,
                             world=WorldSpec(num_clients=4,
                                             profile="heterogeneous",
                                             dropout_p=0.0)))
    assert a.records == b.records
