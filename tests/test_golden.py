"""Golden-trace regression fixtures for two scenario presets.

Tiny seeded per-round record traces ("drift", "churn+flaky-links" —
megastep path, 4 clients, 6 rounds) are committed under tests/golden/;
this test diffs the current engine output against them, so ANY change
to the world-transition semantics, the event accounting or the seeded
draw order shows up as a diff instead of silently rewriting history.

The traces use θ=None cells: every field except loss/accuracy is then
arithmetic over seeded draws and the world trajectory (no filter
thresholds to flip), so accounting compares at 1e-6 while the learned
metrics get a cross-platform float tolerance.

Regenerate (ONLY with an intentional, explained semantics change):

    PYTHONPATH=src python -m tests.test_golden --regen
"""
import dataclasses
import json
import pathlib

import numpy as np
import pytest

try:
    import harness                      # pytest puts tests/ on sys.path
except ModuleNotFoundError:
    from tests import harness           # `python -m tests.test_golden`

GOLDEN_DIR = pathlib.Path(__file__).with_name("golden")
REGEN_CMD = "PYTHONPATH=src python -m tests.test_golden --regen"
PRESETS = {"drift": "drift.json",
           "churn+flaky-links": "churn_flaky.json"}

# accounting is seeded arithmetic -> tight; loss/accuracy cross XLA
# reduction orders on different hosts -> measured-quantity tolerances
TOLERANCES = {"sim_time": dict(rtol=1e-6), "comm_time": dict(rtol=1e-6),
              "idle_time": dict(rtol=1e-6, atol=1e-9),
              "bytes_sent": dict(rtol=1e-9),
              "accept_rate": dict(rtol=1e-9),
              "loss": dict(rtol=2e-3), "accuracy": dict(atol=0.02)}
EXACT = ("round", "updates_applied")


def golden_spec(preset: str):
    return harness.base_spec(scenario=preset, rounds=6, num_clients=4,
                             dropout_p=0.15, theta=None, seed=7)


def compute_trace(preset: str) -> dict:
    res = harness.run_cell(golden_spec(preset), "megastep")
    return {
        "preset": preset,
        "path": "megastep",
        "regen": REGEN_CMD,
        "records": [dataclasses.asdict(r) for r in res.records],
    }


@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_trace_matches_golden(preset):
    path = GOLDEN_DIR / PRESETS[preset]
    golden = json.loads(path.read_text())
    got = compute_trace(preset)
    assert len(got["records"]) == len(golden["records"])
    for i, (g, c) in enumerate(zip(golden["records"], got["records"])):
        for f in EXACT:
            assert c[f] == g[f], \
                (f"{preset} round {i}: {f} changed "
                 f"{g[f]!r} -> {c[f]!r}; if intentional: {REGEN_CMD}")
        for f, tol in TOLERANCES.items():
            np.testing.assert_allclose(
                c[f], g[f], **tol,
                err_msg=(f"{preset} round {i}: {f} drifted from the "
                         f"golden trace; if intentional: {REGEN_CMD}"))


def regen() -> None:
    GOLDEN_DIR.mkdir(exist_ok=True)
    for preset, fname in PRESETS.items():
        trace = compute_trace(preset)
        out = GOLDEN_DIR / fname
        out.write_text(json.dumps(trace, indent=1) + "\n")
        print(f"wrote {out} ({len(trace['records'])} rounds)")


if __name__ == "__main__":
    import sys
    if "--regen" not in sys.argv:
        raise SystemExit(f"usage: {REGEN_CMD}")
    regen()
