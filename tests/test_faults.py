"""Chaos suite (ISSUE 7): deterministic fault injection and every
graceful-degradation path it proves.

Layers covered:
  repro.faults            — injector determinism, schedules, bursts
  serve/engine.py         — bounded queue, deadlines, degraded mode,
                            scorer-fault absorption, zero-drop accounting
  serve/federate.py       — retry/backoff, circuit breaker, join fix
  checkpoint/io + manager — write/read faults, retention, latest_good
  serve/health.py         — unified degradation snapshot

Everything here is seeded: the SAME spec injects the SAME fault
sequence, so assertions are exact, never probabilistic. Heavier
session-level corruption/fallback coverage (both engines, bit-identical
restores) lives in tests/test_checkpoint.py and tests/test_session.py;
this file stays fast enough to run as the CI ``chaos`` step
(``REPRO_SMOKE=1 python -m tests.test_faults``).
"""
import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults
from repro.checkpoint import io as ckpt_io
from repro.checkpoint.io import CheckpointCorruptError
from repro.checkpoint.manager import CheckpointManager
from repro.configs import anomaly_mlp
from repro.faults import BurstSpec, FaultInjector, FaultSpec, InjectedFault
from repro.models import api as model_api
from repro.serve import (DriftMonitor, ModelSlot, QueueFullError,
                         Refederator, ServeEngine, health_snapshot)
from repro.serve import health as health_mod

CFG = anomaly_mlp.SMOKE


def _params(seed=0):
    return model_api.init_params(jax.random.PRNGKey(seed), CFG)


def _flows(seed, n):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, CFG.num_features)).astype(np.float32)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(4, 3)).astype(np.float32),
            "b": rng.normal(size=(3,)).astype(np.float32)}


class _Clock:
    """Injectable monotonic clock for deadline tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------
# the injector itself
# ---------------------------------------------------------------------
class TestFaultInjector:
    def test_same_spec_same_fault_sequence(self):
        spec = FaultSpec(seed=7, scorer_p=0.3, ckpt_read_p=0.6)
        a = FaultInjector(spec)
        b = FaultInjector(spec)
        for site in ("scorer", "ckpt_read"):
            assert [a.poll(site) for _ in range(64)] \
                == [b.poll(site) for _ in range(64)]

    def test_sites_are_independent_streams(self):
        """Interleaving order across sites must not change either
        site's sequence — each site's draw is a function of its own
        call index alone."""
        spec = FaultSpec(seed=3, scorer_p=0.5, publish_p=0.5)
        a = FaultInjector(spec)
        solo_scorer = [a.poll("scorer") for _ in range(20)]
        a2 = FaultInjector(spec)
        solo_publish = [a2.poll("publish") for _ in range(20)]
        b = FaultInjector(spec)
        mixed = [(b.poll("scorer"), b.poll("publish")) for _ in range(20)]
        assert [m[0] for m in mixed] == solo_scorer
        assert [m[1] for m in mixed] == solo_publish

    def test_at_schedule_fires_exact_indices(self):
        inj = FaultInjector(FaultSpec(at={"publish": (0, 3)}))
        assert [inj.poll("publish") for _ in range(5)] \
            == [True, False, False, True, False]

    def test_check_raises_with_site_and_index(self):
        inj = FaultInjector(FaultSpec(at={"refederate": (1,)}))
        inj.check("refederate")                 # call 0: clean
        with pytest.raises(InjectedFault, match="refederate") as ei:
            inj.check("refederate")
        assert ei.value.site == "refederate" and ei.value.index == 1
        assert inj.counts()["refederate"] == {"calls": 2, "fired": 1}

    def test_probability_bounds_validated(self):
        with pytest.raises(ValueError, match="outside"):
            FaultInjector(FaultSpec(scorer_p=1.5))
        with pytest.raises(ValueError, match=">= 0"):
            FaultInjector(FaultSpec(at={"scorer": (-1,)}))
        with pytest.raises(ValueError, match="BurstSpec"):
            FaultInjector(FaultSpec(burst=BurstSpec(period=0)))

    def test_p1_fires_always_p0_never(self):
        inj = FaultInjector(FaultSpec(scorer_p=1.0))
        assert all(inj.poll("scorer") for _ in range(10))
        assert not any(inj.poll("ckpt_write") for _ in range(10))

    def test_burst_spec_is_deterministic_shape(self):
        b = BurstSpec(period=4, mult=8, phase=1)
        assert b.sizes(8, 10) == [10, 80, 10, 10, 10, 80, 10, 10]
        assert b.is_burst(5) and not b.is_burst(4)

    def test_scoped_installs_ambient_and_restores(self):
        assert faults.active() is None
        inj = FaultInjector(FaultSpec(at={"ckpt_read": (0,)}))
        with inj.scoped():
            assert faults.active() is inj
            with pytest.raises(InjectedFault):
                faults.check_active("ckpt_read")
        assert faults.active() is None
        faults.check_active("ckpt_read")        # no-op outside scope

    def test_thread_safety_counts_every_call(self):
        inj = FaultInjector(FaultSpec(seed=1, scorer_p=0.5))
        hits = []

        def worker():
            hits.append(sum(inj.poll("scorer") for _ in range(200)))

        ts = [threading.Thread(target=worker) for _ in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        c = inj.counts()["scorer"]
        assert c["calls"] == 800
        assert c["fired"] == sum(hits)


# ---------------------------------------------------------------------
# engine: admission control + deadlines + degraded mode + absorption
# ---------------------------------------------------------------------
class TestBoundedQueue:
    def test_shed_at_limit_and_zero_drop_of_accepted(self):
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=8,
                          queue_limit=4)
        for i in range(4):
            eng.submit(_flows(i, 1)[0])
        with pytest.raises(QueueFullError, match="queue at limit"):
            eng.submit(_flows(9, 1)[0])
        assert eng.try_submit(_flows(9, 1)[0]) is None
        stats = eng.shutdown()
        assert stats.submitted == stats.served == 4
        assert stats.shed == 2 and stats.dropped == 0

    def test_submit_many_best_effort_skips_shed_rows(self):
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=8,
                          queue_limit=3)
        with pytest.raises(QueueFullError):
            eng.submit_many(_flows(0, 5))
        eng.drain()
        ids = eng.submit_many(_flows(1, 5), best_effort=True)
        assert len(ids) == 3
        stats = eng.shutdown()
        assert stats.served == stats.submitted
        assert stats.shed >= 2 and stats.dropped == 0

    def test_burst_windows_shed_but_never_drop(self):
        burst = BurstSpec(period=3, mult=6, phase=2)
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=16,
                          queue_limit=16)
        for w, size in enumerate(burst.sizes(6, 8)):
            eng.submit_many(_flows(100 + w, size), best_effort=True)
            eng.pump()
        stats = eng.shutdown()
        assert stats.shed > 0                    # bursts overflowed
        assert stats.served == stats.submitted   # accepted all answered
        assert stats.dropped == 0 and stats.errors == 0


class TestDeadlines:
    def test_expired_requests_answered_with_nan(self):
        clock = _Clock()
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=8,
                          now=clock, deadline_ms=10.0)
        eng.submit(_flows(0, 1)[0])                       # default 10ms
        eng.submit(_flows(1, 1)[0], deadline_ms=1000.0)   # override
        clock.t = 0.5                                     # 500ms later
        out = eng.pump()
        assert len(out) == 2
        by_id = {r.request_id: r for r in out}
        assert by_id[0].expired and np.isnan(by_id[0].score)
        assert np.all(np.isnan(by_id[0].probs))
        assert not by_id[1].expired and not np.isnan(by_id[1].score)
        stats = eng.shutdown()
        assert stats.deadline_miss == 1
        assert stats.served == stats.submitted == 2
        assert stats.dropped == 0

    def test_expired_latency_excluded_from_percentiles(self):
        clock = _Clock()
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=8,
                          now=clock)
        eng.submit(_flows(0, 1)[0], deadline_ms=1.0)
        clock.t = 9.0                                     # huge miss
        eng.submit(_flows(1, 1)[0])
        eng.drain()
        stats = eng.shutdown()
        assert stats.deadline_miss == 1
        # the 9-second expired wait must not pollute scoring latency
        assert stats.p99_ms < 9000.0


class TestDegradedMode:
    def _overload_engine(self, monitor=None):
        # ema_decay=0 -> the EMA IS the instantaneous depth, so the
        # hysteresis thresholds are exact and the test deterministic
        return ServeEngine(ModelSlot(_params()), CFG, max_batch=8,
                           monitor=monitor, queue_limit=40,
                           degrade_high=0.5, degrade_low=0.25,
                           ema_decay=0.0)

    def test_hysteresis_enters_and_exits(self):
        eng = self._overload_engine()
        eng.submit_many(_flows(0, 30))      # depth 30 > 0.5*40
        eng.pump()
        assert eng.degraded
        eng.drain()                          # depth falls under 0.25*40
        eng.pump()                           # one empty pump re-evaluates
        assert not eng.degraded
        stats = eng.shutdown()
        assert stats.degraded_pumps >= 1
        assert stats.served == stats.submitted and stats.dropped == 0

    def test_degraded_pumps_skip_drift_monitor(self):
        x = _flows(0, 256)
        mon = DriftMonitor.from_sample(x, np.abs(x[:, 0]), threshold=0.5,
                                       patience=1)
        eng = self._overload_engine(monitor=mon)
        before = float(np.asarray(mon.state.count))
        eng.submit_many(_flows(1, 30) + 5.0)   # wildly shifted traffic
        eng.pump()
        assert eng.degraded
        # shifted windows scored while degraded never feed the monitor
        assert float(np.asarray(mon.state.count)) == before
        assert not mon.triggered
        eng.drain()
        eng.shutdown()


class TestScorerFaults:
    def test_transient_fault_requeues_in_order(self):
        inj = FaultInjector(FaultSpec(at={"scorer": (0,)}))
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=8,
                          injector=inj)
        eng.submit_many(_flows(0, 5))
        assert eng.pump() == []                  # absorbed, requeued
        assert eng.stats().errors == 1
        assert eng.stats().pending == 5 and eng.stats().inflight == 0
        out = eng.pump()                         # retry succeeds
        assert [r.request_id for r in out] == [0, 1, 2, 3, 4]
        stats = eng.shutdown()
        assert stats.served == stats.submitted == 5
        assert stats.dropped == 0 and stats.errors == 1

    def test_persistent_fault_raises_after_budget(self):
        inj = FaultInjector(FaultSpec(scorer_p=1.0))
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=8,
                          injector=inj, max_dispatch_retries=2)
        eng.submit_many(_flows(0, 3))
        assert eng.pump() == []                  # failures 1, 2 absorbed
        assert eng.pump() == []
        with pytest.raises(InjectedFault, match="scorer"):
            eng.pump()                           # consecutive > budget
        stats = eng.stats()
        assert stats.pending == 3 and stats.inflight == 0
        assert stats.dropped == 0                # still owed, not lost

    def test_success_resets_consecutive_failure_budget(self):
        inj = FaultInjector(FaultSpec(at={"scorer": (0, 2)}))
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=8,
                          injector=inj, max_dispatch_retries=1)
        eng.submit_many(_flows(0, 2))
        assert eng.pump() == []                  # fault #0 absorbed
        assert len(eng.pump()) == 2              # success resets counter
        eng.submit_many(_flows(1, 2))
        assert eng.pump() == []                  # fault #2: budget fresh
        assert len(eng.pump()) == 2
        stats = eng.shutdown()
        assert stats.served == stats.submitted == 4
        assert stats.errors == 2 and stats.dropped == 0

    def test_chaos_mix_never_drops_accepted(self):
        """Scorer faults + deadlines + bounded queue + bursts at once:
        every accepted request is answered exactly once."""
        inj = FaultInjector(FaultSpec(seed=5, scorer_p=0.25,
                                      burst=BurstSpec(period=3, mult=5)))
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=16,
                          queue_limit=32, deadline_ms=60_000.0,
                          injector=inj)
        accepted, answered = [], []
        for w, size in enumerate(inj.spec.burst.sizes(9, 8)):
            accepted += eng.submit_many(_flows(w, size), best_effort=True)
            answered += [r.request_id for r in eng.pump()]
        while eng.pending:
            answered += [r.request_id for r in eng.pump()]
        stats = eng.shutdown()
        assert sorted(answered) == sorted(accepted)
        assert stats.dropped == 0
        assert stats.errors > 0                  # chaos actually fired
        assert stats.shed > 0


# ---------------------------------------------------------------------
# refederator: retry / backoff / breaker / join
# ---------------------------------------------------------------------
class _ScriptedRefederator(Refederator):
    """Refederator whose attempts follow a boolean script (True =
    raise) — exercises the retry/backoff/breaker machinery without
    running real federation sessions."""

    def __init__(self, script, **kw):
        kw.setdefault("background", False)
        kw.setdefault("sleep", lambda s: self.sleeps.append(s))
        self.sleeps = []
        super().__init__(ModelSlot(_params()), lambda k: None,
                         ckpt_dir="/tmp/unused", **kw)
        self._script = list(script)
        self.attempts = 0

    def _attempt(self, k):
        i = self.attempts
        self.attempts += 1
        if i < len(self._script) and self._script[i]:
            raise RuntimeError(f"scripted failure #{i}")


class TestRefederatorRetries:
    def test_retries_until_success_within_budget(self):
        r = _ScriptedRefederator([True, True, False], max_retries=2)
        assert r.fire()
        assert r.attempts == 3 and r.completed == 1 and r.retries == 2
        assert r.last_outcome == "ok" and r.last_error is None
        assert r.breaker_state == "closed" and r.consecutive_failures == 0
        assert len(r.sleeps) == 2               # backoff between attempts

    def test_backoff_is_exponential_capped_and_deterministic(self):
        kw = dict(max_retries=3, backoff_base=0.5, backoff_factor=4.0,
                  max_backoff=3.0, jitter=0.1, seed=11)
        a = _ScriptedRefederator([True] * 4, **kw)
        b = _ScriptedRefederator([True] * 4, **kw)
        a.fire()
        b.fire()
        assert a.sleeps == b.sleeps             # seeded jitter
        assert len(a.sleeps) == 3
        for i, s in enumerate(a.sleeps):
            base = min(3.0, 0.5 * 4.0 ** i)
            assert base <= s <= base * 1.1      # jitter in [0, 10%]
        assert a.last_outcome == "failed" and a.consecutive_failures == 1

    def test_breaker_opens_after_threshold_consecutive_failures(self):
        r = _ScriptedRefederator([True] * 10, max_retries=0,
                                 breaker_threshold=2, breaker_cooldown=1)
        assert r.fire() and r.breaker_state == "closed"
        assert r.fire() and r.breaker_state == "open"
        assert r.consecutive_failures == 2
        # cooldown: the next trigger is swallowed without an attempt
        before = r.attempts
        assert not r.fire()
        assert r.attempts == before and r.skipped == 1
        # then the half-open probe runs ONE attempt and re-opens
        assert r.fire()
        assert r.attempts == before + 1
        assert r.breaker_state == "open" and r.retries == 0

    def test_half_open_probe_success_recloses(self):
        r = _ScriptedRefederator([True, True, False, False],
                                 max_retries=0, breaker_threshold=2,
                                 breaker_cooldown=0)
        r.fire()
        r.fire()
        assert r.breaker_state == "open"
        assert r.fire()                          # cooldown 0 -> probe now
        assert r.breaker_state == "closed"
        assert r.completed == 1 and r.consecutive_failures == 0
        assert r.fire() and r.completed == 2     # normal service resumed

    def test_success_resets_consecutive_failures(self):
        r = _ScriptedRefederator([True, False, True], max_retries=0,
                                 breaker_threshold=2)
        r.fire()
        assert r.consecutive_failures == 1
        r.fire()
        assert r.consecutive_failures == 0 and r.last_outcome == "ok"
        r.fire()
        assert r.consecutive_failures == 1       # not 2: no breaker
        assert r.breaker_state == "closed"

    def test_injected_refederate_fault_counts_like_any_failure(self):
        inj = FaultInjector(FaultSpec(refederate_p=1.0))
        r = Refederator(ModelSlot(_params()), lambda k: None,
                        ckpt_dir="/tmp/unused", background=False,
                        max_retries=0, breaker_threshold=1, injector=inj,
                        sleep=lambda s: None)
        r.fire()
        assert isinstance(r.last_error, InjectedFault)
        assert r.breaker_state == "open"

    def test_join_timeout_keeps_thread_and_busy(self):
        release = threading.Event()

        class _Blocking(_ScriptedRefederator):
            def _attempt(self, k):
                release.wait(10)

        r = _Blocking([], background=True)
        assert r.fire()
        assert r.join(timeout=0.05) is False     # still running
        assert r.busy                            # satellite (a): not lied
        assert not r.fire() and r.skipped == 1   # coalesced, not doubled
        release.set()
        assert r.join(timeout=5) is True
        assert not r.busy
        assert r.completed == 1


# ---------------------------------------------------------------------
# checkpoint IO + manager under chaos
# ---------------------------------------------------------------------
class TestCheckpointChaos:
    def test_write_fault_never_damages_previous_artifact(self, tmp_path):
        path = str(tmp_path / "t.msgpack")
        first = _tree(0)
        ckpt_io.save(path, first)
        inj = FaultInjector(FaultSpec(at={"ckpt_write": (0,)}))
        with inj.scoped():
            with pytest.raises(InjectedFault, match="ckpt_write"):
                ckpt_io.save(path, _tree(1))
        assert ckpt_io.verify(path)
        got = ckpt_io.restore(path, _tree(9))
        np.testing.assert_array_equal(np.asarray(got["w"]), first["w"])

    def test_read_fault_raises_and_verify_reports_bad(self, tmp_path):
        path = str(tmp_path / "t.msgpack")
        ckpt_io.save(path, _tree(0))
        inj = FaultInjector(FaultSpec(ckpt_read_p=1.0))
        with inj.scoped():
            with pytest.raises(InjectedFault, match="ckpt_read"):
                ckpt_io.restore(path, _tree(0))
            assert not ckpt_io.verify(path)
        assert ckpt_io.verify(path)              # healthy outside chaos

    def test_manager_retention_prunes_to_keep(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for i in range(4):
            mgr.save(_tree(i), now=float(i))
        hist = mgr.history()
        assert len(hist) == 2
        assert hist[0].endswith("_00003.msgpack")   # newest first
        assert hist[1].endswith("_00002.msgpack")
        assert os.path.exists(mgr.path())

    def test_latest_good_skips_corrupt_canonical(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(_tree(0), now=0.0)
        mgr.save(_tree(1), now=1.0)
        with open(mgr.path(), "r+b") as f:        # bit-flip the newest
            f.seek(40)
            c = f.read(1)
            f.seek(40)
            f.write(bytes([c[0] ^ 0xFF]))
        good = mgr.latest_good()
        assert good == mgr.history()[0]           # newest VERIFIED copy
        got = ckpt_io.restore(good, _tree(9))
        np.testing.assert_array_equal(np.asarray(got["w"]), _tree(1)["w"])

    def test_manager_restore_fallback_recovers(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(_tree(0), now=0.0)
        with open(mgr.path(), "wb") as f:
            f.write(b"garbage" * 100)
        with pytest.raises(CheckpointCorruptError, match="t_latest|corrupt"):
            mgr.restore(_tree(9))
        got = mgr.restore(_tree(9), fallback=True)
        np.testing.assert_array_equal(np.asarray(got["w"]), _tree(0)["w"])

    def test_manager_restore_injected_read_fault_falls_back(self, tmp_path):
        """An injected read fault on the canonical path degrades to the
        history copy (whose read, one call later, is clean)."""
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(_tree(0), now=0.0)
        inj = FaultInjector(FaultSpec(at={"ckpt_read": (0,)}))
        with inj.scoped():
            got = mgr.restore(_tree(9), fallback=True)
        np.testing.assert_array_equal(np.asarray(got["w"]), _tree(0)["w"])

    def test_fallback_with_nothing_good_reraises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        mgr.save(_tree(0), now=0.0)
        for p in [mgr.path()] + mgr.history():
            with open(p, "wb") as f:
                f.write(b"\x00" * 64)
        with pytest.raises(CheckpointCorruptError):
            mgr.restore(_tree(9), fallback=True)


# ---------------------------------------------------------------------
# health snapshot
# ---------------------------------------------------------------------
class TestHealth:
    def test_ok_engine_snapshot(self):
        eng = ServeEngine(ModelSlot(_params(), model=CFG.name), CFG,
                          max_batch=8, queue_limit=16)
        eng.submit_many(_flows(0, 4))
        eng.drain()
        h = health_snapshot(eng)
        assert h.status == "ok" and h.healthy
        assert h.served == 4 and h.shed == 0 and h.dropped == 0
        assert h.queue_limit == 16 and h.model_version == 0
        json.dumps(h.to_dict())                  # JSON-ready, by contract

    def test_shed_marks_degraded_status(self):
        eng = ServeEngine(ModelSlot(_params()), CFG, max_batch=8,
                          queue_limit=2)
        eng.submit_many(_flows(0, 5), best_effort=True)
        eng.drain()
        h = health_snapshot(eng)
        assert h.status == "degraded" and h.shed == 3

    def test_open_breaker_is_critical(self):
        r = _ScriptedRefederator([True] * 3, max_retries=0,
                                 breaker_threshold=1)
        r.fire()
        h = health_snapshot(refederator=r)
        assert h.status == "critical"
        assert h.breaker_state == "open"
        assert h.last_refederation == "failed"
        assert h.consecutive_failures == 1
        assert h.last_error and "scripted failure" in h.last_error

    def test_snapshot_composes_all_sources(self):
        x = _flows(0, 256)
        mon = DriftMonitor.from_sample(x, np.abs(x[:, 0]), threshold=0.5,
                                       patience=1)
        eng = ServeEngine(ModelSlot(_params(), model=CFG.name), CFG,
                          max_batch=8, monitor=mon)
        r = _ScriptedRefederator([False])
        r.fire()
        h = health_snapshot(eng, refederator=r)
        assert h.last_refederation == "ok"
        assert h.refederations_completed == 1
        assert h.drift_triggered is False
        assert h.status == "ok"

    def test_status_constants_exported(self):
        assert health_mod.STATUS_OK == "ok"
        assert health_mod.STATUS_DEGRADED == "degraded"
        assert health_mod.STATUS_CRITICAL == "critical"


if __name__ == "__main__":        # the CI chaos step's entry point
    raise SystemExit(pytest.main([__file__, "-q"]))
