"""Beyond-paper extensions: error-feedback quantized updates + hierarchical
cross-pod selective sync."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression, hierarchy


def _tree(key):
    return {"w": jax.random.normal(key, (5, 37)) * 0.01,
            "b": jax.random.normal(jax.random.fold_in(key, 1), (11,)) * 0.01}


class TestCompression:
    def test_roundtrip_error_bounded(self):
        key = jax.random.PRNGKey(0)
        upd = _tree(key)
        err = compression.init_error_state(upd)
        q, s, n, new_err = compression.compress_update(upd, err)
        back = compression.decompress_update(q, s, upd)
        for a, b, e in zip(jax.tree.leaves(upd), jax.tree.leaves(back),
                           jax.tree.leaves(new_err)):
            np.testing.assert_allclose(np.asarray(a),
                                       np.asarray(b) + np.asarray(e),
                                       rtol=1e-5, atol=1e-7)

    def test_error_feedback_removes_bias(self):
        """Mean of EF-compressed updates converges to the true mean."""
        key = jax.random.PRNGKey(1)
        g = _tree(key)                       # constant update every round
        err = compression.init_error_state(g)
        acc = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), g)
        R = 50
        for _ in range(R):
            q, s, n, err = compression.compress_update(g, err)
            back = compression.decompress_update(q, s, g)
            acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                               acc, back)
        for a, x in zip(jax.tree.leaves(acc), jax.tree.leaves(g)):
            # accumulated dequantized sum ~ R * g (bias killed by EF)
            np.testing.assert_allclose(np.asarray(a) / R, np.asarray(x),
                                       rtol=0.02, atol=5e-5)

    def test_transport_is_4x_smaller(self):
        key = jax.random.PRNGKey(2)
        upd = {"w": jax.random.normal(key, (4096,))}
        err = compression.init_error_state(upd)
        q, s, n, _ = compression.compress_update(upd, err)
        assert compression.transport_bytes(q, s) < 4096 * 4 / 3.5
        assert compression.compression_ratio(upd) > 3.5


class TestHierarchy:
    def _pods(self, P=4, seed=0, spread=0.01):
        key = jax.random.PRNGKey(seed)
        base = _tree(key)
        return jax.tree.map(
            lambda x: x[None] + spread * jax.random.normal(
                jax.random.fold_in(key, 7), (P,) + x.shape), base), base

    def test_no_sync_until_due(self):
        pods, base = self._pods()
        st = hierarchy.init_pod_sync(base)
        new_pods, st2, m = hierarchy.maybe_pod_sync(pods, st, sync_every=5)
        assert float(m["synced"]) == 0.0
        for a, b in zip(jax.tree.leaves(new_pods), jax.tree.leaves(pods)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert int(st2.rounds_since_sync) == 1

    def test_sync_broadcasts_consensus(self):
        pods, base = self._pods()
        st = hierarchy.init_pod_sync(base)
        new_pods, st2, m = hierarchy.maybe_pod_sync(pods, st, sync_every=1)
        assert float(m["synced"]) == 1.0
        for leaf in jax.tree.leaves(new_pods):
            # all pods identical after sync
            ref = np.asarray(leaf[0], np.float32)
            for p in range(leaf.shape[0]):
                np.testing.assert_allclose(np.asarray(leaf[p], np.float32),
                                           ref, rtol=1e-5, atol=1e-6)
        assert int(st2.rounds_since_sync) == 0

    def test_sync_mean_when_bootstrap(self):
        """First sync (no reference) = plain mean of pod deltas."""
        pods, base = self._pods(P=2, spread=0.5)
        st = hierarchy.init_pod_sync(base)
        new_pods, _, m = hierarchy.maybe_pod_sync(pods, st, sync_every=1)
        want = jax.tree.map(lambda x: x.mean(0), pods)
        for a, b in zip(jax.tree.leaves(new_pods), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(a[0], np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-4, atol=1e-5)

    def test_divergent_pod_filtered_after_reference(self):
        pods, base = self._pods(P=4, spread=0.01)
        st = hierarchy.init_pod_sync(base)
        pods1, st, _ = hierarchy.maybe_pod_sync(pods, st, sync_every=1)
        # move 3 pods along +delta, 1 pod opposite
        delta = jax.tree.map(lambda x: 0.05 * jnp.sign(
            jax.random.normal(jax.random.PRNGKey(9), x.shape[1:])), pods1)
        moved = jax.tree.map(
            lambda p, d: p + d[None] * jnp.where(
                jnp.arange(p.shape[0]).reshape((-1,) + (1,) * (p.ndim - 1))
                == 3, -1.0, 1.0), pods1, delta)
        # set the reference to the +delta direction
        st = st._replace(
            global_ref_sign=jax.tree.map(
                lambda d: jnp.sign(d).astype(jnp.int8), delta),
            rounds_since_sync=jnp.asarray(3, jnp.int32))
        _, _, m = hierarchy.maybe_pod_sync(moved, st, sync_every=1,
                                           theta=0.65)
        assert float(m["synced"]) == 1.0
        assert float(m["pod_accept"]) == 0.75, "the divergent pod must be cut"