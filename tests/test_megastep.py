"""Seeded equivalence of the compiled cohort megastep vs the reference
per-client loop (core/megastep.py vs FederatedSimulation loop path), plus
parameter-arena pack/unpack round-trips across every registered config."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (DataSpec, ExperimentSpec, WorldSpec, get_strategy,
                       run_experiment)
from repro.configs import anomaly_mlp, registry
from repro.core import async_engine as ae
from repro.kernels import arena as arena_mod
from repro.models import api

SMALL = dict(model="anomaly-mlp-smoke",
             data=DataSpec(n_samples=1500, eval_samples=300),
             world=WorldSpec(num_clients=5, profile="heterogeneous"),
             rounds=4, seed=0)


def _pair(strategy, **kw):
    spec = ExperimentSpec(**{**SMALL, **kw, "strategy": strategy})
    mega = run_experiment(spec)
    loop = run_experiment(dataclasses.replace(spec, megastep=False))
    return mega, loop


def _assert_equivalent(mega, loop):
    """Same RNG draw order -> identical event accounting; fp trajectories
    coincide up to vmap-vs-loop reduction order (documented regolden rule:
    the megastep is pinned to the loop within these tolerances)."""
    assert len(mega.records) == len(loop.records)
    for a, b in zip(mega.records, loop.records):
        assert a.round == b.round
        assert a.updates_applied == b.updates_applied
        assert a.accept_rate == b.accept_rate
        assert a.bytes_sent == b.bytes_sent
        np.testing.assert_allclose(a.sim_time, b.sim_time, rtol=1e-9)
        np.testing.assert_allclose(a.comm_time, b.comm_time, rtol=1e-9)
        np.testing.assert_allclose(a.idle_time, b.idle_time,
                                   rtol=1e-9, atol=1e-12)
        np.testing.assert_allclose(a.accuracy, b.accuracy, atol=2e-3)
        np.testing.assert_allclose(a.loss, b.loss, rtol=1e-3)


# ---------------------------------------------------------------------------
# trajectory equivalence: sync + async + theta + quantize
# ---------------------------------------------------------------------------

def test_megastep_matches_loop_sync_fedavg():
    _assert_equivalent(*_pair(get_strategy("fedavg").build(batch_size=32)))


def test_megastep_matches_loop_sync_theta():
    _assert_equivalent(*_pair(
        get_strategy("cmfl").build(batch_size=32, theta=0.55)))


def test_megastep_matches_loop_async_full():
    """The paper's full framework: async quorum + θ + selection +
    dynamic batch + checkpointing + dropout (multiple shape groups)."""
    _assert_equivalent(*_pair(
        get_strategy("ours").build(batch_size=64),
        world=WorldSpec(num_clients=6, profile="heterogeneous",
                        dropout_p=0.25)))


def test_megastep_matches_loop_quantized():
    """int8 + batched error feedback on the wire (arena EF state)."""
    _assert_equivalent(*_pair(
        get_strategy("ours").build(batch_size=32, dynamic_batch=False,
                                   quantize_updates=True)))


def test_megastep_matches_loop_semi_async():
    """Bounded-staleness (semi-async) aggregation: both host paths drop
    the same too-stale arrivals and stay trajectory-equivalent."""
    from repro.api import ScheduleSpec
    _assert_equivalent(*_pair(
        get_strategy("ours").build(batch_size=32, dynamic_batch=False),
        schedule=ScheduleSpec(kind="semi-async", quorum=0.5,
                              max_staleness=1)))


def test_megastep_dispatch_count_is_o1():
    """The whole point: compiled dispatches per round must not scale with
    the client count (the loop path pays >= 1 per client per round).
    Equal shard sizes -> one cohort shape group -> one training dispatch;
    skewed shards only add the (bounded) power-of-two group count."""
    clients, ev = _world(10, equal=True)
    strat = get_strategy("ours").build(batch_size=32, dynamic_batch=False)
    profiles = ae.uniform_profiles(10)
    mega = ae.FederatedSimulation(anomaly_mlp.SMOKE, clients, ev, strat,
                                  profiles, seed=0, megastep=True)
    loop = ae.FederatedSimulation(anomaly_mlp.SMOKE, clients, ev,
                                  dataclasses.replace(strat), profiles,
                                  seed=0, megastep=False)
    mega.run(3)
    loop.run(3)
    per_round_mega = mega.dispatches / 3
    per_round_loop = loop.dispatches / 3
    assert per_round_mega <= 4          # megastep + apply + unpack + eval
    assert per_round_loop >= 10         # >= 1 per client per round


def _world(n_clients, seed=0, n=1500, equal=False):
    from repro.data import partition, synthetic
    cfg = anomaly_mlp.SMOKE
    X, y = synthetic.make_unsw_like(seed, n, cfg.num_features,
                                    cfg.num_classes)
    if equal:
        per = n // n_clients
        parts = [np.arange(i * per, (i + 1) * per) for i in range(n_clients)]
    else:
        parts = partition.dirichlet_partition(y, n_clients, alpha=0.7,
                                              seed=seed)
    clients = [{"x": X[p], "y": y[p]} for p in parts]
    Xe, ye = synthetic.make_unsw_like(seed + 1, 300, cfg.num_features,
                                      cfg.num_classes)
    return clients, {"x": Xe, "y": ye}


# ---------------------------------------------------------------------------
# scanned path: device-resident control plane, R rounds per dispatch
# ---------------------------------------------------------------------------

def _scan_pair(strategy, R=4, rounds=8, **kw):
    """Same scanned trajectory at rounds_per_dispatch=R vs =1."""
    spec = ExperimentSpec(**{**SMALL, **kw, "strategy": strategy,
                             "rounds": rounds},
                          rounds_per_dispatch=R)
    grouped = run_experiment(spec)
    single = run_experiment(dataclasses.replace(spec, rounds_per_dispatch=1))
    return grouped, single, R


def _assert_scan_equivalent(grouped, single, R):
    """Per-round keys fold from the absolute round index, so dispatch
    grouping must not change ANY scan-computed metric bit; accuracy is
    only measured at dispatch boundaries (params are identical there)."""
    assert len(grouped.records) == len(single.records)
    for i, (a, b) in enumerate(zip(grouped.records, single.records)):
        assert a.round == b.round
        assert a.sim_time == b.sim_time
        assert a.comm_time == b.comm_time
        assert a.idle_time == b.idle_time
        assert a.bytes_sent == b.bytes_sent
        assert a.updates_applied == b.updates_applied
        assert a.accept_rate == b.accept_rate
        assert a.loss == b.loss
        if (i + 1) % R == 0 or i == len(grouped.records) - 1:
            assert a.accuracy == b.accuracy


def test_scanned_grouping_invariant_sync():
    _assert_scan_equivalent(*_scan_pair(
        get_strategy("fedavg").build(batch_size=32)))


def test_scanned_grouping_invariant_sync_theta():
    _assert_scan_equivalent(*_scan_pair(
        get_strategy("cmfl").build(batch_size=32, theta=0.55)))


def test_scanned_grouping_invariant_async_full():
    """async quorum + θ + selection + dynamic batch + dropout +
    checkpointing — the paper's full framework, device control plane."""
    _assert_scan_equivalent(*_scan_pair(
        get_strategy("ours").build(batch_size=64, select_fraction=0.75),
        world=WorldSpec(num_clients=6, profile="heterogeneous",
                        dropout_p=0.25)))


def test_scanned_grouping_invariant_quantized():
    _assert_scan_equivalent(*_scan_pair(
        get_strategy("ours").build(batch_size=32, dynamic_batch=False,
                                   quantize_updates=True)))


def test_scanned_grouping_invariant_semi_async():
    """The device control plane honors the semi-async staleness cutoff
    identically at any dispatch grouping."""
    from repro.api import ScheduleSpec
    _assert_scan_equivalent(*_scan_pair(
        get_strategy("ours").build(batch_size=32, dynamic_batch=False),
        schedule=ScheduleSpec(kind="semi-async", quorum=0.5,
                              max_staleness=1)))


def test_scanned_partial_final_dispatch():
    """rounds not divisible by R: the remainder runs as a second trace
    and the trajectory still matches the R=1 grouping exactly."""
    _assert_scan_equivalent(*_scan_pair(
        get_strategy("fedavg").build(batch_size=32), R=3, rounds=7))


def test_scanned_deterministic():
    spec = ExperimentSpec(**{**SMALL, "strategy":
                             get_strategy("ours").build(batch_size=32)},
                          rounds_per_dispatch=4)
    a = run_experiment(spec)
    b = run_experiment(dataclasses.replace(spec))
    for x, y in zip(a.records, b.records):
        for f in ("round", "sim_time", "comm_time", "idle_time",
                  "bytes_sent", "updates_applied", "accept_rate", "loss"):
            assert getattr(x, f) == getattr(y, f), f
        # pre-first-eval rounds carry NaN accuracy (NaN != NaN)
        np.testing.assert_equal(x.accuracy, y.accuracy)


def test_scanned_amortized_dispatches_below_one_per_round():
    """The tentpole: R rounds of select/train/filter/aggregate/control
    per compiled call -> dispatches per round fall BELOW 1 (amortized),
    vs the per-round megastep's ~4 and the loop's O(clients)."""
    clients, ev = _world(8, equal=True)
    strat = get_strategy("ours").build(batch_size=32, dynamic_batch=False)
    profiles = ae.uniform_profiles(8)
    sim = ae.FederatedSimulation(anomaly_mlp.SMOKE, clients, ev, strat,
                                 profiles, seed=0, megastep=True,
                                 rounds_per_dispatch=8)
    sim.run(16)
    per_round = sim.dispatches / 16
    # 2 scan dispatches + 2 evals + 2 lazy unpacks over 16 rounds
    assert per_round < 1.0, sim.dispatches


def test_scanned_selection_prefers_reliable_clients():
    """Flaky clients (high dropout) must be selected less often once the
    availability EMA learns — the device selection feedback loop works
    end to end."""
    import jax.numpy as jnp
    from repro.core import control as control_mod

    clients, ev = _world(6, equal=True)
    strat = get_strategy("ours").build(batch_size=32, dynamic_batch=False,
                                       select_fraction=0.5)
    profiles = ae.uniform_profiles(6)
    for cid in (0, 1):
        profiles[cid] = dataclasses.replace(profiles[cid], dropout_p=0.9)
    sim = ae.FederatedSimulation(anomaly_mlp.SMOKE, clients, ev, strat,
                                 profiles, seed=0, megastep=True,
                                 rounds_per_dispatch=5)
    sim.run(25)
    ctl = sim._scan_ctl
    scores = np.asarray(control_mod.score(ctl))
    assert scores[:2].max() < scores[2:].min(), scores


# ---------------------------------------------------------------------------
# eval_every
# ---------------------------------------------------------------------------

def test_eval_every_skips_and_carries_forward():
    spec = ExperimentSpec(**{**SMALL, "rounds": 5,
                             "strategy": get_strategy("fedavg").build(
                                 batch_size=32)},
                          eval_every=2)
    res = run_experiment(spec)
    accs = [r.accuracy for r in res.records]
    assert accs[1] == accs[0]           # skipped round carries forward
    assert accs[3] == accs[2]
    # the final round is always evaluated, training still progressed
    assert np.isfinite(accs[4])
    full = run_experiment(ExperimentSpec(
        **{**SMALL, "rounds": 5,
           "strategy": get_strategy("fedavg").build(batch_size=32)}))
    np.testing.assert_allclose(accs[4], full.records[4].accuracy, atol=1e-6)


def test_eval_every_validated():
    with pytest.raises(ValueError, match="eval_every"):
        ExperimentSpec(**SMALL, eval_every=0).validate()


# ---------------------------------------------------------------------------
# arena pack/unpack round-trip across all registered configs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", registry.ASSIGNED_ARCHS + ["anomaly-mlp"])
def test_arena_roundtrip_all_configs(arch):
    cfg = registry.get_config(arch, smoke=True)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    arena = arena_mod.ParamArena(params)
    mat = arena.pack(params)
    assert mat.shape == (arena.rows, arena.lane)
    assert arena.rows * arena.lane >= arena.n
    back = arena.unpack(mat)
    assert jax.tree_util.tree_structure(back) \
        == jax.tree_util.tree_structure(params)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(params)):
        assert a.dtype == b.dtype and a.shape == b.shape
        # f32 staging is lossless for f32/bf16 leaves -> exact round-trip
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_arena_cohort_roundtrip_and_signs():
    cfg = anomaly_mlp.SMOKE
    params = api.init_params(jax.random.PRNGKey(1), cfg)
    arena = arena_mod.ParamArena(params)
    C = 3
    stacked = jax.tree.map(
        lambda p: jnp.stack([p * (i + 1) for i in range(C)]), params)
    mat = arena.pack_cohort(stacked)
    assert mat.shape == (C, arena.rows, arena.lane)
    back = arena.unpack_cohort(mat)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(stacked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # single-client packs agree with cohort rows
    one = arena.pack(jax.tree.map(lambda x: x[1], stacked))
    np.testing.assert_array_equal(np.asarray(one), np.asarray(mat[1]))
    # padding of a sign matrix uses the -2 sentinel (never counts aligned)
    from repro.core import alignment
    ref = arena.pack_signs(alignment.tree_sign(params))
    pad = np.asarray(ref).reshape(-1)[arena.n:]
    assert (pad == -2).all()
