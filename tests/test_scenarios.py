"""Dynamic-world scenario engine: property-based cross-path parity
(hypothesis stub -> seeded random sweeps), scenario invariants, and the
θ-filter byzantine-rejection guarantee.

The heavy pairwise machinery lives in tests/harness.py (also runnable
standalone as the CI `scenario-matrix` step); these tests drive it with
RANDOM ScenarioSpecs so every new world transition is born under the
loop≡megastep≡scanned contract instead of growing its own ad-hoc test.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import harness
from repro.api import (ByzantineSpec, ChurnSpec, DriftSpec, DropoutSchedule,
                       ExperimentSpec, LinkSpec, SCENARIO_PRESETS,
                       ScenarioSpec, SpecError, resolve_scenario,
                       run_experiment)
from repro.core import scenario as scenario_mod


def _scenario(drift_rate, churn_period, leave_frac, link_sigma,
              dropout_scale, n_byz) -> ScenarioSpec:
    """Assemble a ScenarioSpec from drawn knobs (0/empty disables)."""
    return ScenarioSpec(
        drift=DriftSpec(rate=drift_rate) if drift_rate > 0 else None,
        churn=(ChurnSpec(period=churn_period, leave_frac=leave_frac)
               if leave_frac > 0 else None),
        links=LinkSpec(bw_sigma=link_sigma, lat_sigma=link_sigma)
        if link_sigma > 0 else None,
        dropout=(DropoutSchedule(boundaries=(2,),
                                 scales=(1.0, dropout_scale))
                 if dropout_scale != 1.0 else None),
        byzantine=ByzantineSpec(n_byz=n_byz) if n_byz > 0 else None)


# ---------------------------------------------------------------------------
# property: loop ≡ megastep under random dynamic worlds
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(drift_rate=st.floats(0.0, 0.15), churn_period=st.integers(1, 3),
       leave_frac=st.floats(0.0, 0.5), link_sigma=st.floats(0.0, 0.5),
       dropout_scale=st.floats(0.5, 3.0), n_byz=st.integers(0, 2))
def test_host_paths_agree_on_random_scenarios(drift_rate, churn_period,
                                              leave_frac, link_sigma,
                                              dropout_scale, n_byz):
    scn = _scenario(drift_rate, churn_period, leave_frac, link_sigma,
                    dropout_scale, n_byz)
    spec = harness.base_spec(scenario=scn, rounds=3, num_clients=4,
                             dropout_p=0.2, n_samples=900)
    results = harness.differential(spec, paths=("loop", "megastep"))
    assert set(results) == {"loop", "megastep"}


# ---------------------------------------------------------------------------
# property: dispatch grouping changes nothing on the scanned path
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(drift_rate=st.floats(0.0, 0.15), leave_frac=st.floats(0.0, 0.5),
       link_sigma=st.floats(0.0, 0.5), n_byz=st.integers(0, 2))
def test_scan_grouping_invariant_on_random_scenarios(drift_rate,
                                                     leave_frac,
                                                     link_sigma, n_byz):
    scn = _scenario(drift_rate, 2, leave_frac, link_sigma, 2.0, n_byz)
    spec = harness.base_spec(scenario=scn, rounds=4, num_clients=4,
                             dropout_p=0.2, n_samples=900)
    harness.differential(spec, paths=("scanned1", "scanned4"))


# ---------------------------------------------------------------------------
# property: host ≡ scanned ≡ spmd event accounting when it must be
# trajectory-independent (no θ, no dropout, full participation)
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(leave_frac=st.floats(0.0, 0.5), link_sigma=st.floats(0.0, 0.5))
def test_cross_family_accounting_parity(leave_frac, link_sigma):
    scn = _scenario(0.0, 2, leave_frac, link_sigma, 1.0, 0)
    # iid shards keep every client above the cohort batch size (the
    # spmd engine needs ONE rectangular cohort shape)
    spec = harness.base_spec(scenario=scn, rounds=3, num_clients=4,
                             theta=None, n_samples=900, partition="iid")
    harness.differential(spec, paths=("megastep", "scanned1", "spmd"))


# ---------------------------------------------------------------------------
# property: churn mask conservation + byte-accounting invariants
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(period=st.integers(1, 4), leave_frac=st.floats(0.05, 0.6),
       n=st.integers(2, 9))
def test_churn_roster_is_conserved_and_rotates(period, leave_frac, n):
    """The replayed live roster (the harness's engine-independent
    oracle) keeps a constant live count and rotates membership."""
    scn = ScenarioSpec(churn=ChurnSpec(period=period,
                                       leave_frac=leave_frac))
    views = scenario_mod.replay(scn, n, rounds=4 * period)
    leave = min(int(round(leave_frac * n)), n - 1)
    rosters = set()
    for wv in views:
        assert int(wv["live"].sum()) == n - leave     # conservation
        rosters.add(tuple(np.nonzero(~wv["live"])[0]))
    if leave > 0 and n > 2 * leave:
        assert len(rosters) > 1                        # membership moves


def test_churn_updates_bounded_by_live_count():
    spec = harness.base_spec(scenario="churn", rounds=6, num_clients=6)
    res = harness.run_cell(spec, "scanned4")
    harness.check_invariants(res, spec, label="scanned4")
    views = scenario_mod.replay(spec.resolve_scenario(), 6,
                                len(res.records))
    lives = [int(wv["live"].sum()) for wv in views]
    assert any(lv < 6 for lv in lives)             # churn actually bites
    for rec, lv in zip(res.records, lives):
        assert rec.updates_applied <= lv


# ---------------------------------------------------------------------------
# scenario semantics
# ---------------------------------------------------------------------------

def test_drift_changes_trajectory_but_round0_is_static():
    base = harness.base_spec(rounds=3, theta=None)
    drift = dataclasses.replace(base, scenario="drift")
    a = run_experiment(base)
    b = run_experiment(drift)
    # linear drift has amplitude 0 at round 0 -> identical first round
    assert a.records[0].loss == b.records[0].loss
    # ... and a different world afterwards
    assert a.records[-1].loss != b.records[-1].loss
    # drift never touches the event accounting
    for x, y in zip(a.records, b.records):
        assert x.sim_time == y.sim_time
        assert x.bytes_sent == y.bytes_sent


def test_flaky_links_reprice_comm_time():
    base = harness.base_spec(rounds=4, theta=None)
    flaky = dataclasses.replace(
        base, scenario=ScenarioSpec(links=LinkSpec(bw_sigma=0.5,
                                                   lat_sigma=0.5)))
    a = run_experiment(base)
    b = run_experiment(flaky)
    assert a.records[-1].comm_time != b.records[-1].comm_time
    # same roster, same transmissions — only the wire got re-priced
    for x, y in zip(a.records, b.records):
        assert x.updates_applied == y.updates_applied
        assert x.bytes_sent == y.bytes_sent


def test_dropout_regime_switch_gates_failures():
    """scales=(0, 8): failures are impossible before the boundary and
    near-certain after it (p=0.25·8 clips to 1)."""
    scn = ScenarioSpec(dropout=DropoutSchedule(boundaries=(3,),
                                               scales=(0.0, 8.0)))
    spec = harness.base_spec(scenario=scn, rounds=6, dropout_p=0.25)
    sim_spec = harness.path_spec(spec, "megastep")
    from repro.api import ExperimentSession
    s = ExperimentSession.open(sim_spec)
    s.run(3)
    sim = s._driver.sim
    assert len(sim.failure_log) == 0               # regime 1: p scaled to 0
    s.run(3)
    assert len(sim.failure_log) == 3 * 5           # regime 2: p clipped to 1


def test_byzantine_rejected_on_host_and_scanned_paths():
    spec = harness.base_spec(scenario="byzantine", rounds=8,
                             theta=0.6, partition="iid")
    for path in ("megastep", "scanned4"):
        harness.assert_byzantine_rejected(spec, path)


def test_byzantine_without_theta_is_not_filtered():
    """No θ-filter -> corrupted updates land; accept_rate stays 1."""
    spec = harness.base_spec(scenario="byzantine", rounds=3, theta=None)
    res = harness.run_cell(spec, "megastep")
    assert all(r.accept_rate == 1.0 for r in res.records)


# ---------------------------------------------------------------------------
# spec plumbing + validation
# ---------------------------------------------------------------------------

def test_presets_resolve_and_validate():
    for name in SCENARIO_PRESETS:
        scn = resolve_scenario(name)
        if name == "static":
            assert scn is None                     # inactive normalizes
        else:
            assert scn.validate() is scn


def test_inactive_scenario_normalizes_to_none():
    assert resolve_scenario(ScenarioSpec()) is None
    assert resolve_scenario(None) is None


def test_scenario_validation_collects_issues():
    bad = ScenarioSpec(
        drift=DriftSpec(rate=-1.0, mode="warp"),
        churn=ChurnSpec(period=0, leave_frac=1.0),
        dropout=DropoutSchedule(boundaries=(5, 3), scales=(1.0,)))
    spec = harness.base_spec(scenario=bad)
    with pytest.raises(SpecError) as ei:
        spec.validate()
    fields = {i.field for i in ei.value.issues}
    assert {"scenario.drift.mode", "scenario.drift.rate",
            "scenario.churn.period", "scenario.churn.leave_frac",
            "scenario.dropout.scales",
            "scenario.dropout.boundaries"} <= fields


def test_all_byzantine_world_rejected():
    """n_byz must leave at least one honest client (the θ-filter has no
    honest majority to form a reference otherwise)."""
    spec = harness.base_spec(
        scenario=ScenarioSpec(byzantine=ByzantineSpec(n_byz=5)),
        num_clients=5)
    with pytest.raises(SpecError, match="n_byz"):
        spec.validate()
    dataclasses.replace(
        spec, scenario=ScenarioSpec(
            byzantine=ByzantineSpec(n_byz=4))).validate()


def test_unknown_preset_rejected():
    spec = harness.base_spec(scenario="chaos-monkey")
    with pytest.raises(SpecError, match="chaos-monkey"):
        spec.validate()


def test_drift_rejected_for_token_datasets():
    spec = ExperimentSpec(model="qwen2-1.5b",
                          scenario="drift",
                          data=dataclasses.replace(
                              harness.base_spec().data, partition="iid"))
    with pytest.raises(SpecError, match="drift"):
        spec.validate()


def test_epsilon_exploration_pool_excludes_churned_clients():
    """The device selector's ε-greedy pool must be live-only (matching
    the host oracle's live-restricted pool): with ε=1 every slot
    explores, and no churned-out client may ever be swapped in."""
    import jax.numpy as jnp
    from repro.core import control as control_mod

    n, k = 8, 3
    live = jnp.asarray([True, False, True, False, True, True, True, False])
    scores = jnp.where(live, jnp.linspace(1.0, 0.1, n), -jnp.inf)
    rng = np.random.default_rng(0)
    for _ in range(20):
        cohort = control_mod.select_topk_epsilon(
            scores, k, epsilon=1.0,
            eps_u=jnp.asarray(rng.random(k), jnp.float32),
            pick_u=jnp.asarray(rng.random(k), jnp.float32), live=live)
        assert bool(live[cohort].all()), np.asarray(cohort)
    # live=None keeps the oracle-pinned historical behavior (any client
    # may be explored)
    seen = set()
    for _ in range(20):
        cohort = control_mod.select_topk_epsilon(
            scores, k, epsilon=1.0,
            eps_u=jnp.asarray(rng.random(k), jnp.float32),
            pick_u=jnp.asarray(rng.random(k), jnp.float32))
        seen.update(np.asarray(cohort).tolist())
    assert seen - {0, 2, 4, 5, 6}          # dead ids reachable w/o mask


def test_world_step_is_grouping_independent():
    """The world trajectory is a function of the absolute round index:
    replaying rounds one-by-one equals any chunked replay."""
    scn = SCENARIO_PRESETS["dynamic"]
    a = scenario_mod.replay(scn, 6, rounds=8)
    ws = scenario_mod.init_world(scn, 6)
    for r in range(8):
        ws = scenario_mod.world_step(ws, r, scn, 6)
        if r in (3, 7):
            wv = scenario_mod.host_view(ws)
            for k, v in a[r].items():
                np.testing.assert_array_equal(v, wv[k], err_msg=k)
