"""Flash-attention Pallas kernel vs oracles + the §Perf backend findings
kept as executable documentation."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention, flash_bytes
from repro.models import layers as L


def _naive(q, k, v, causal):
    S = q.shape[1]
    hd = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        i = jnp.arange(S)[:, None]
        j = jnp.arange(S)[None, :]
        s = jnp.where(j <= i, s, -1e30)
    return jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(s, -1),
                      v.astype(jnp.float32))


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("S", [256, 512])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_naive(causal, S, dtype):
    key = jax.random.PRNGKey(0)
    BH, hd = 4, 64
    q = jax.random.normal(key, (BH, S, hd)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (BH, S, hd)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (BH, S, hd)).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128)
    want = _naive(q, k, v, causal)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_blockwise_oracle_matches_naive_gqa():
    """The XLA blockwise path is the kernel's GQA oracle (sliding window)."""
    key = jax.random.PRNGKey(1)
    B, S, K, G, hd = 2, 1024, 2, 3, 32
    q = jax.random.normal(key, (B, S, K * G, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, hd))

    def naive(sw):
        s = L._gqa_scores(q, k)
        i = jnp.arange(S)[:, None]
        j = jnp.arange(S)[None, :]
        mask = j <= i
        if sw:
            mask &= (i - j) < sw
        s = jnp.where(mask[None, None, None], s, -1e30)
        return L._gqa_out(jax.nn.softmax(s, -1), v, jnp.float32)

    for sw in (None, 200):
        got = L.blockwise_attention(q, k, v, causal=True, sliding_window=sw,
                                    out_dtype=jnp.float32, block=256)
        np.testing.assert_allclose(np.asarray(got), np.asarray(naive(sw)),
                                   rtol=3e-4, atol=3e-4)


def test_blockwise_grad_flows():
    key = jax.random.PRNGKey(2)
    B, S, H, hd = 1, 512, 2, 32
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    g = jax.grad(lambda q: L.blockwise_attention(
        q, k, v, causal=True, out_dtype=jnp.float32).sum())(q)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_flash_traffic_model_beats_naive():
    """The kernel's HBM model must beat naive S² scores for real shapes."""
    B, S, H, hd = 16, 4096, 25, 64          # hymba train, per device
    naive_scores = B * H * S * S * 4        # fp32 scores, one materialization
    assert flash_bytes(B, S, H, hd) < naive_scores / 30


def test_backend_gather_limitation_microrepro():
    """§Perf P-D finding: batched gathers are not partitioned along batch
    dims by this backend's SPMD — executable documentation. If this test
    ever FAILS (no all-gather emitted), the MoE dispatch note in
    EXPERIMENTS.md should be revisited."""
    if jax.device_count() < 2:
        pytest.skip("needs multi-device SPMD (dry-run process only)")