"""Per-architecture smoke tests (deliverable f).

For every assigned architecture: instantiate the REDUCED variant (2
layers, d_model <= 512, <= 4 experts) and run one federated train step and
one decode step on CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.shapes import SMOKE_SHAPES
from repro.core import fl_step
from repro.models import api

ARCHS = registry.ASSIGNED_ARCHS


def _smoke_batch(cfg, clients=2, per_client=2, seq=32, seed=0):
    rng = np.random.default_rng(seed)
    toks = seq - (cfg.num_patches if cfg.family == "vlm" else 0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(clients, per_client, toks))),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(clients, per_client, toks))),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(clients, per_client, cfg.num_patches,
                             cfg.d_model)), cfg.compute_dtype)
    if cfg.family == "audio":
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(clients, per_client, cfg.encoder_seq,
                             cfg.d_model)), cfg.compute_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_reduced_config(arch):
    cfg = registry.get_config(arch, smoke=True)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = registry.get_config(arch, smoke=True)
    state = fl_step.init_state(jax.random.PRNGKey(0), cfg)
    step = fl_step.build_fl_train_step(cfg, theta=0.65, donate=False)
    batch = _smoke_batch(cfg)
    state2, metrics = step(state, batch)
    loss0 = float(metrics["loss"])
    assert np.isfinite(loss0), f"{arch}: non-finite loss"
    assert 0.0 <= float(metrics["accept_rate"]) <= 1.0
    # a second step must also be finite and params must have moved
    state3, metrics2 = step(state2, batch)
    assert np.isfinite(float(metrics2["loss"]))
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(state3.params)))
    assert moved, f"{arch}: params did not move"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    sh = SMOKE_SHAPES["decode_32k"]
    cfg = registry.config_for_shape(arch, "decode_32k", smoke=True)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    cache = api.init_cache(cfg, sh.global_batch, sh.seq_len)
    cache["step"] = jnp.asarray(sh.seq_len // 2, jnp.int32)
    batch = {"tokens": jnp.zeros((sh.global_batch, 1), jnp.int32)}
    logits, cache2 = api.decode_step(params, cache, batch, cfg)
    assert logits.shape == (sh.global_batch, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert int(cache2["step"]) == sh.seq_len // 2 + 1


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if a not in registry.LONG_CTX_SKIP])
def test_smoke_long_context_decode(arch):
    sh = SMOKE_SHAPES["long_500k"]
    cfg = registry.config_for_shape(arch, "long_500k", smoke=True)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    cache = api.init_cache(cfg, sh.global_batch, sh.seq_len)
    if cfg.sliding_window:
        kv = [l for l in jax.tree.leaves(cache) if getattr(l, "ndim", 0) == 5]
        for leaf in kv:
            assert leaf.shape[2] <= cfg.sliding_window, \
                "long-context cache must be windowed, not full-length"
    cache["step"] = jnp.asarray(sh.seq_len - 1, jnp.int32)
    batch = {"tokens": jnp.zeros((sh.global_batch, 1), jnp.int32)}
    logits, _ = api.decode_step(params, cache, batch, cfg)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_whisper_skips_long_context():
    with pytest.raises(ValueError):
        registry.config_for_shape("whisper-tiny", "long_500k", smoke=True)


def test_anomaly_mlp_smoke():
    from repro.configs import anomaly_mlp
    from repro.models import mlp_detector
    cfg = anomaly_mlp.SMOKE
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(16, cfg.num_features)), jnp.float32)
    y = jnp.zeros((16,), jnp.int32)
    loss = api.loss_fn(params, {"x": x, "y": y}, cfg)
    assert np.isfinite(float(loss))
    acc = mlp_detector.accuracy(params, {"x": x, "y": y}, cfg)
    assert 0.0 <= float(acc) <= 1.0
