"""Device control plane (core/control.py) pinned to the host oracles:
``AdaptiveClientSelector`` (selection EMAs + ε-greedy top-k),
``BatchSizeController`` (power-of-two straggler feedback),
``local_step_count`` and the unified staleness weight."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, control
from repro.core.async_engine import StrategyConfig, local_step_count
from repro.core.batchsize import BatchSizeController, ClientMetrics
from repro.core.selection import AdaptiveClientSelector

N = 8


def _obs_stream(regime: str, rounds: int = 40, seed: int = 0):
    """Seeded per-round observation batches mimicking each engine config:
    'sync' (everyone delivers, barrier times), 'async' (quorum spread +
    dropouts), 'theta' (filter failures -> passed=False observations)."""
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        k = int(rng.integers(2, N + 1))
        cohort = rng.choice(N, size=k, replace=False)
        if regime == "sync":
            delivered = np.ones(k, bool)
            passed = np.ones(k, bool)
        elif regime == "async":
            delivered = rng.random(k) > 0.3
            passed = np.ones(k, bool)
        else:                                  # theta
            delivered = rng.random(k) > 0.1
            passed = rng.random(k) > 0.4
        times = rng.uniform(0.1, 5.0, size=k)
        yield cohort, delivered, passed, times


@pytest.mark.parametrize("regime", ["sync", "async", "theta"])
def test_observe_and_score_match_selector_oracle(regime):
    sel = AdaptiveClientSelector(N, epsilon=0.0, seed=0)
    ctl = control.init_control(N)
    for cohort, delivered, passed, times in _obs_stream(regime):
        for c, d, p, t in zip(cohort, delivered, passed, times):
            sel.observe(int(c), delivered=bool(d), passed=bool(p),
                        round_time=float(t))
        ctl = control.observe(ctl, jnp.asarray(cohort),
                              mask=jnp.ones(len(cohort), bool),
                              delivered=jnp.asarray(delivered),
                              passed=jnp.asarray(passed),
                              round_time=jnp.asarray(times, jnp.float32))
    host = np.array([[sel.records[c].availability, sel.records[c].pass_rate,
                      sel.records[c].round_time] for c in range(N)])
    dev = np.stack([np.asarray(ctl.avail), np.asarray(ctl.pass_rate),
                    np.asarray(ctl.round_time)], axis=1)
    np.testing.assert_allclose(dev, host, rtol=2e-5, atol=2e-6)
    host_scores = np.array([sel.score(c) for c in range(N)])
    np.testing.assert_allclose(np.asarray(control.score(ctl)), host_scores,
                               rtol=2e-5, atol=2e-6)


def test_two_phase_observation_matches_recovered_client():
    """A dropped-then-checkpoint-recovered client is observed twice
    (delivered=False, then delivered=True) — observe_round must match."""
    sel = AdaptiveClientSelector(4, seed=0)
    ctl = control.init_control(4)
    cohort = jnp.asarray([0, 1, 2, 3])
    failed = jnp.asarray([True, False, True, False])
    active = jnp.asarray([True, True, False, True])   # 2 failed, no ckpt
    passed = jnp.asarray([True, False, False, True])
    times = jnp.asarray([2.0, 1.0, 9.9, 0.5], jnp.float32)
    for c in (0, 2):
        sel.observe(c, delivered=False)
    for c, p, t in ((0, True, 2.0), (1, False, 1.0), (3, True, 0.5)):
        sel.observe(c, delivered=True, passed=p, round_time=t)
    ctl = control.observe_round(ctl, cohort, failed=failed, active=active,
                                passed=passed, round_time=times)
    host = np.array([[sel.records[c].availability, sel.records[c].pass_rate,
                      sel.records[c].round_time] for c in range(4)])
    dev = np.stack([np.asarray(ctl.avail), np.asarray(ctl.pass_rate),
                    np.asarray(ctl.round_time)], axis=1)
    np.testing.assert_allclose(dev, host, rtol=1e-6, atol=1e-7)


def test_select_topk_matches_oracle_without_exploration():
    sel = AdaptiveClientSelector(N, epsilon=0.0, seed=3)
    ctl = control.init_control(N)
    for cohort, delivered, passed, times in _obs_stream("theta", seed=3):
        for c, d, p, t in zip(cohort, delivered, passed, times):
            sel.observe(int(c), delivered=bool(d), passed=bool(p),
                        round_time=float(t))
        ctl = control.observe(ctl, jnp.asarray(cohort),
                              mask=jnp.ones(len(cohort), bool),
                              delivered=jnp.asarray(delivered),
                              passed=jnp.asarray(passed),
                              round_time=jnp.asarray(times, jnp.float32))
    for k in (1, 3, 5, N):
        host = sel.select(k)
        dev = list(np.asarray(
            control.select_topk_epsilon(control.score(ctl), k)))
        assert host == dev, (k, host, dev)


def _host_select_with_draws(scores, k, epsilon, eps_u, pick_u):
    """The AdaptiveClientSelector.select algorithm with the randomness
    injected (uniforms instead of Generator calls) — python reference."""
    order = list(np.argsort(-np.asarray(scores), kind="stable"))
    chosen = order[:k]
    chosen_set = set(chosen)
    pool = [c for c in range(len(scores)) if c not in chosen_set]
    for i in range(k):
        if pool and eps_u[i] < epsilon:
            j = int(pick_u[i] * len(pool))
            chosen[i] = pool.pop(min(j, len(pool) - 1))
    return chosen


def test_select_topk_epsilon_decision_function():
    rng = np.random.default_rng(7)
    for trial in range(20):
        scores = rng.uniform(0.0, 1.0, size=N).astype(np.float32)
        k = int(rng.integers(1, N))
        eps_u = rng.random(k).astype(np.float32)
        pick_u = rng.random(k).astype(np.float32)
        host = _host_select_with_draws(scores, k, 0.6, eps_u, pick_u)
        dev = list(np.asarray(control.select_topk_epsilon(
            jnp.asarray(scores), k, 0.6, eps_u=jnp.asarray(eps_u),
            pick_u=jnp.asarray(pick_u))))
        assert host == dev, (trial, host, dev)


def test_select_topk_explores_beyond_topk():
    scores = jnp.asarray(np.linspace(1.0, 0.1, N), jnp.float32)
    picks = set()
    for s in range(30):
        key = jax.random.PRNGKey(s)
        picks.update(np.asarray(
            control.select_topk(scores, 3, key=key, epsilon=1.0)).tolist())
    assert len(picks) > 3, "epsilon-greedy must explore beyond the top-k"


def test_batch_feedback_matches_controller_oracle():
    rng = np.random.default_rng(1)
    ctrl = BatchSizeController()
    sizes = []
    for cid in range(N):
        m = ClientMetrics(compute=float(rng.uniform(0.2, 4.0)),
                          memory=float(rng.uniform(0.3, 1.0)),
                          latency=float(rng.uniform(0.0, 0.3)))
        sizes.append(ctrl.initial(cid, m))
    ctl = control.init_control(N, batch_sizes=sizes)
    for _ in range(30):
        k = int(rng.integers(1, N + 1))
        cohort = np.sort(rng.choice(N, size=k, replace=False))
        times = rng.uniform(0.05, 8.0, size=k)
        ctrl.feedback({int(c): float(t) for c, t in zip(cohort, times)})
        ctl = control.batch_feedback(
            ctl, jnp.asarray(cohort), jnp.asarray(times, jnp.float32),
            jnp.ones(k, bool))
        host = [ctrl.assignment[c] for c in range(N)]
        assert np.asarray(ctl.batch).tolist() == host


def test_local_steps_matches_host():
    st = StrategyConfig(local_epochs=2, max_samples_per_round=4096)
    ns, bs = [], []
    host = []
    for n in (17, 100, 640, 5000, 20000):
        for b in (32, 64, 128, 512, 1024):
            ns.append(n)
            bs.append(b)
            host.append(local_step_count(n, b, st))
    dev = control.local_steps(jnp.asarray(ns), jnp.asarray(bs),
                              st.local_epochs, st.max_samples_per_round)
    assert np.asarray(dev).tolist() == host


def test_staleness_weight_unified_over_tau():
    """Regression: one implementation serves host + device for τ∈{0..8}."""
    for alpha0 in (0.6, 1.0):
        for tau in range(9):
            closed = np.float32(alpha0) * np.float32(1.0 + tau) \
                ** np.float32(-0.5)
            one = float(aggregation.staleness_weight(tau, alpha0))
            host = aggregation.staleness_weight_host(tau, alpha0)
            vec = aggregation.staleness_weights_np(np.arange(9), alpha0)
            np.testing.assert_allclose(one, closed, rtol=1e-6)
            np.testing.assert_allclose(host, one, rtol=0)    # same impl
            np.testing.assert_allclose(vec[tau], one, rtol=0)


def test_grad_norm_and_lr_scale_rules():
    ctl = control.init_control(4)
    cohort = jnp.asarray([0, 1, 2, 3])
    norms = jnp.asarray([0.5, 2.0, 0.5, 2.0], jnp.float32)
    valid = jnp.asarray([True, True, False, False])
    ctl = control.grad_norm_update(ctl, cohort, norms, valid)
    np.testing.assert_allclose(np.asarray(ctl.grad_norm),
                               [0.75, 1.5, 1.0, 1.0])
    ctl = control.lr_scale_update(ctl, cohort, norms, valid)
    np.testing.assert_allclose(np.asarray(ctl.lr_scale),
                               [1.05, 0.9, 1.0, 1.0])


def test_staleness_and_checkpoint_counters():
    ctl = control.init_control(4)
    cohort = jnp.asarray([0, 2])
    ctl = control.staleness_update(ctl, cohort,
                                   jnp.asarray([True, False]))
    assert np.asarray(ctl.staleness).tolist() == [0, 1, 1, 1]
    ctl = control.checkpoint_update(ctl, cohort,
                                    jnp.asarray([True, False]))
    assert np.asarray(ctl.has_ckpt).tolist() == [True, False, False, False]
