"""Minimal, deterministic stand-in for the ``hypothesis`` package.

The container image does not ship hypothesis, and nothing may be pip
installed; this stub implements exactly the API surface the suite uses
(``given``, ``settings``, ``strategies.integers/floats/lists``) so the
property tests still run as seeded random sweeps. When the real package
is importable, tests/conftest.py leaves it alone and this file is inert.

Semantics: ``@given`` re-runs the test ``max_examples`` times (from the
stacked ``@settings``) drawing from a per-test deterministic RNG; each
scalar strategy yields its bounds first, then uniform samples — cheap
edge coverage without real shrinking.
"""
from __future__ import annotations

import functools
import types
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw
        self._calls = 0

    def example(self, rng):
        i = self._calls
        self._calls += 1
        return self._draw(rng, i)


def integers(min_value, max_value):
    def draw(rng, i):
        if i == 0:
            return int(min_value)
        if i == 1:
            return int(max_value)
        return int(rng.integers(min_value, max_value + 1))
    return _Strategy(draw)


def floats(min_value, max_value, **_):
    def draw(rng, i):
        if i == 0:
            return float(min_value)
        if i == 1:
            return float(max_value)
        return float(rng.uniform(min_value, max_value))
    return _Strategy(draw)


def booleans():
    return _Strategy(lambda rng, i: bool(rng.integers(0, 2)))


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng, i: seq[int(rng.integers(0, len(seq)))])


def lists(elements, min_size=0, max_size=10):
    def draw(rng, i):
        k = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(k)]
    return _Strategy(draw)


def just(value):
    return _Strategy(lambda rng, i: value)


def settings(max_examples=20, deadline=None, **_):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def assume(condition):
    if not condition:
        raise _Unsatisfied()


class _Unsatisfied(Exception):
    pass


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", 20))
            seed = zlib.crc32(f"{fn.__module__}:{fn.__qualname__}"
                              .encode())
            rng = np.random.default_rng(seed)
            ran = 0
            for _ in range(n):
                drawn = [s.example(rng) for s in arg_strategies]
                kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *drawn, **kwargs, **kw)
                    ran += 1
                except _Unsatisfied:
                    continue
            assert ran > 0, "stub hypothesis: every example was assumed away"
        # pytest follows __wrapped__ to the original signature and would
        # treat the drawn parameters as fixtures; hide it
        del wrapper.__wrapped__
        return wrapper
    return deco


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"


# `from hypothesis import strategies as st` resolves this attribute;
# conftest also registers it as the "hypothesis.strategies" module.
strategies = types.ModuleType("hypothesis.strategies")
for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
              "just"):
    setattr(strategies, _name, globals()[_name])
