"""Adaptive client selection + dynamic batch-size controller (§IV-A, §V-C)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.batchsize import (BatchSizeController, ClientMetrics,
                                  assign_batch_size, capacity_score)
from repro.core.selection import AdaptiveClientSelector


@settings(max_examples=30, deadline=None)
@given(st.floats(0.05, 8.0), st.floats(0.05, 8.0), st.floats(0.0, 1.0),
       st.floats(0.0, 0.5))
def test_batch_monotone_in_compute(c1, c2, mem, lat):
    lo, hi = sorted((c1, c2))
    b_lo = assign_batch_size(ClientMetrics(lo, mem, lat))
    b_hi = assign_batch_size(ClientMetrics(hi, mem, lat))
    assert b_lo <= b_hi


def test_batch_bounds_and_examples():
    # paper §IV-A: high-capacity -> 512+; low-capacity -> 64
    big = assign_batch_size(ClientMetrics(6.0, 1.0, 0.0))
    small = assign_batch_size(ClientMetrics(0.05, 0.2, 0.3))
    assert big >= 512
    assert small == 64
    for m in [ClientMetrics(x, 0.5, 0.1) for x in (0.01, 1.0, 100.0)]:
        assert 64 <= assign_batch_size(m) <= 1024


def test_latency_penalizes_capacity():
    fast = capacity_score(ClientMetrics(1.0, 1.0, 0.0))
    slow = capacity_score(ClientMetrics(1.0, 1.0, 0.5))
    assert slow < fast


def test_controller_demotes_stragglers():
    ctrl = BatchSizeController()
    for cid in range(4):
        ctrl.initial(cid, ClientMetrics(1.0, 1.0, 0.0))
    base = dict(ctrl.assignment)
    ctrl.feedback({0: 10.0, 1: 1.0, 2: 1.0, 3: 1.0})
    assert ctrl.assignment[0] == max(base[0] // 2, 64)


def test_selector_prefers_reliable_clients():
    sel = AdaptiveClientSelector(6, epsilon=0.0, seed=0)
    for _ in range(20):
        sel.observe(0, delivered=False)                 # flaky
        sel.observe(1, delivered=True, round_time=10.0)  # slow
        for c in (2, 3, 4, 5):
            sel.observe(c, delivered=True, round_time=0.5)
    top = sel.select(4)
    assert 0 not in top
    assert 1 not in top


def test_selector_epsilon_explores():
    sel = AdaptiveClientSelector(10, epsilon=1.0, seed=0)
    for _ in range(5):
        sel.observe(0, delivered=False)
    picks = set()
    for _ in range(20):
        picks.update(sel.select(3))
    assert len(picks) > 3, "epsilon-greedy must explore beyond the top-k"


def test_selector_scores_bounded():
    sel = AdaptiveClientSelector(3)
    rng = np.random.default_rng(0)
    for _ in range(50):
        sel.observe(int(rng.integers(3)), delivered=bool(rng.random() < 0.7),
                    passed=bool(rng.random() < 0.8),
                    round_time=float(rng.uniform(0.1, 5.0)))
    for c in range(3):
        assert 0.0 <= sel.score(c) <= 1.0
