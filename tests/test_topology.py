"""repro.topology: spec validation, the seeded tier tree, the pure-jnp
`topology_step` pinned to a seeded numpy oracle (sync cadence, per-tier
theta veto, bootstrap has_ref, all-vetoed fallback, link accounting),
and the engine-level contracts — topology is a measurement layer that
NEVER perturbs the flat trajectory, runs identically across
loop/megastep/scanned paths, survives checkpoint/restore bit-exactly,
and a single-tier tree IS today's path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSession, ExperimentSpec, SpecError
from repro.kernels.arena import ParamArena
from repro.topology import (PARAM_BYTES, TierSpec, TopologyRuntime,
                            TopologySpec, TOPOLOGY_PRESETS, build_tree,
                            child_valid, empty_topology, leaf_pods,
                            resolve_topology)
from tests import harness

THREE_TIER = TopologySpec(tiers=(
    TierSpec("edge", fanout=4, sync_every=1),
    TierSpec("region", fanout=3, sync_every=2, theta=0.3),
    TierSpec("global", sync_every=4)))


# ---------------------------------------------------------------------------
# spec + resolver
# ---------------------------------------------------------------------------

def test_resolve_topology_forms():
    assert resolve_topology(None) is None
    assert resolve_topology(TopologySpec()) is None          # inactive
    assert resolve_topology(TopologySpec(tiers=(TierSpec("a"),))) is None
    spec = resolve_topology("two-tier-pods")
    assert isinstance(spec, TopologySpec) and spec.active()
    assert resolve_topology(spec) is spec
    with pytest.raises(ValueError):
        resolve_topology("no-such-preset")
    with pytest.raises(TypeError):
        resolve_topology(42)


def test_presets_validate():
    for name, spec in TOPOLOGY_PRESETS.items():
        assert spec.active(), name
        assert spec.issues() == [], name


@pytest.mark.parametrize("tiers,field", [
    # non-root tier without fanout
    ((TierSpec("a"), TierSpec("b", sync_every=2)), "fanout"),
    # leaf tier must sync every round
    ((TierSpec("a", fanout=2, sync_every=2),
      TierSpec("b", sync_every=4)), "sync_every"),
    # nested cadences must be multiples
    ((TierSpec("a", fanout=2), TierSpec("b", fanout=2, sync_every=3),
      TierSpec("c", sync_every=4)), "sync_every"),
    # duplicate names
    ((TierSpec("a", fanout=2), TierSpec("a", sync_every=2)), "tiers"),
])
def test_spec_issues(tiers, field):
    issues = TopologySpec(tiers=tiers).issues()
    assert issues, "expected validation issues"
    assert any(field in f for f, _v, _h in issues), issues


def test_experiment_spec_rejects_bad_topology():
    with pytest.raises(SpecError):
        ExperimentSpec(rounds=1, topology="no-such-preset").validate()
    with pytest.raises(SpecError):
        ExperimentSpec(rounds=1, topology=TopologySpec(tiers=(
            TierSpec("a"), TierSpec("b", sync_every=3),
            TierSpec("c", sync_every=4)))).validate()


# ---------------------------------------------------------------------------
# tier tree: seeded static assignment, pointwise at 1M
# ---------------------------------------------------------------------------

def test_tree_pod_counts():
    tree = build_tree(THREE_TIER, num_clients=25)
    assert tree.pods == (7, 3, 1)          # ceil(25/4), ceil(7/3), root
    assert tree.num_boundaries == 2
    assert tree.groups == (3, 3)           # region fanout; root absorbs


def test_leaf_assignment_is_a_balanced_permutation():
    n = 64
    tree = build_tree(TOPOLOGY_PRESETS["two-tier-pods"], n)
    ids = np.arange(n, dtype=np.int64)
    pods = leaf_pods(tree, ids)
    assert pods.min() >= 0 and pods.max() < tree.pods[0]
    # affine bijection -> perfectly balanced when fanout | n
    counts = np.bincount(pods, minlength=tree.pods[0])
    assert (counts == tree.leaf_fanout).all()
    # seeded: a different seed gives a different assignment
    other = build_tree(dataclasses.replace(
        TOPOLOGY_PRESETS["two-tier-pods"], assignment_seed=5), n)
    assert (pods != leaf_pods(other, ids)).any()


def test_leaf_assignment_pointwise_at_1m():
    # non-resident million-client worlds ask for SINGLE ids; the int64
    # host math must not wrap (ids * mult overflows int32 well below 1M)
    spec = TOPOLOGY_PRESETS["edge-region-global"]
    n = 1_000_000
    tree = build_tree(spec, n)
    some = np.array([0, 1, 999_999, 123_456], dtype=np.int64)
    pods = leaf_pods(tree, some)
    assert pods.min() >= 0 and pods.max() < tree.pods[0]
    # pointwise == vectorized on a sample
    sample = np.linspace(0, n - 1, 257, dtype=np.int64)
    all_at_once = leaf_pods(tree, sample)
    one_by_one = np.array([int(leaf_pods(tree, np.array([c]))[0])
                           for c in sample])
    np.testing.assert_array_equal(all_at_once, one_by_one)


def test_child_valid_masks_padding():
    tree = build_tree(THREE_TIER, num_clients=25)    # pods (7, 3, 1)
    v0 = child_valid(tree, 0)                        # (3 parents, 3 group)
    assert v0.shape == (3, 3)
    assert v0.sum() == 7                             # 7 real leaf pods
    v1 = child_valid(tree, 1)                        # (1 root, 3 group)
    assert v1.sum() == 3


# ---------------------------------------------------------------------------
# topology_step vs a seeded numpy oracle
# ---------------------------------------------------------------------------

def _arena():
    return ParamArena({"w": jnp.zeros((5, 7)), "b": jnp.zeros((7,))})


def _oracle(spec, tree, arena, rounds, deltas_seq, w_seq, pods):
    """Independent numpy re-implementation of the accumulate-and-sync
    semantics (engine.TopologyRuntime.step)."""
    rows, lane, n = arena.rows, arena.lane, arena.n
    vmask = np.asarray(arena.valid_mask())
    B = tree.num_boundaries
    accum = [np.zeros((tree.pods[b], rows, lane), np.float32)
             for b in range(B)]
    ref = [np.where(vmask, np.int8(0), np.int8(-2))[None].repeat(
        tree.pods[b + 1], axis=0) for b in range(B)]
    has_ref = [np.zeros(tree.pods[b + 1], bool) for b in range(B)]
    stats = {k: np.zeros(B) for k in ("syncs", "accepts", "vetoes")}
    for r in range(rounds):
        d, w = deltas_seq[r], w_seq[r]
        for i in range(len(w)):
            accum[0][pods[i]] += w[i] * d[i]
        for b in range(B):
            if (r + 1) % spec.tiers[b + 1].sync_every:
                continue
            parents, group = tree.pods[b + 1], tree.groups[b]
            kids = np.zeros((parents * group, rows, lane), np.float32)
            kids[:tree.pods[b]] = accum[b]
            kids = kids.reshape(parents, group, rows, lane)
            valid = np.asarray(child_valid(tree, b))
            signs = np.sign(kids).astype(np.int8)
            counts = (signs == ref[b][:, None]).reshape(
                parents, group, -1).sum(-1)
            ratios = counts / max(n, 1)
            theta = spec.tiers[b + 1].theta
            passed = valid if theta is None else (ratios >= theta) & valid
            passed = np.where(~has_ref[b][:, None], valid, passed)
            none = passed.sum(1) == 0
            passed = np.where(none[:, None], valid, passed)
            wf = passed.astype(np.float32)
            agg = np.einsum("pg,pgrl->prl", wf, kids) \
                / np.maximum(wf.sum(1), 1e-9)[:, None, None]
            ref[b] = np.where(vmask[None], np.sign(agg).astype(np.int8),
                              np.int8(-2))
            has_ref[b][:] = True
            accum[b][:] = 0.0
            if b + 1 < B:
                accum[b + 1] += agg
            stats["syncs"][b] += 1
            stats["accepts"][b] += wf.sum()
            stats["vetoes"][b] += tree.pods[b] - wf.sum()
    return accum, ref, has_ref, stats


@pytest.mark.parametrize("theta", [None, 0.3])
def test_topology_step_matches_numpy_oracle(theta):
    arena = _arena()
    n_clients, rounds = 25, 8
    spec = TopologySpec(tiers=(
        TierSpec("edge", fanout=4),
        TierSpec("region", fanout=3, sync_every=2, theta=theta),
        TierSpec("global", sync_every=4, theta=theta)))
    rt = TopologyRuntime(spec, n_clients, arena)
    state = rt.init()
    rng = np.random.default_rng(11)
    deltas_seq = [rng.normal(size=(n_clients, arena.rows, arena.lane))
                  .astype(np.float32) for _ in range(rounds)]
    # zero out arena padding like packed deltas would be
    pad = np.asarray(arena.valid_mask())
    deltas_seq = [d * pad[None] for d in deltas_seq]
    w_seq = [rng.uniform(0, 1, n_clients).astype(np.float32)
             for _ in range(rounds)]
    pods = np.asarray(rt.pod_of)
    step = jax.jit(rt.step)
    for r in range(rounds):
        state = step(state, jnp.int32(r), jnp.asarray(deltas_seq[r]),
                     jnp.asarray(w_seq[r]))
    accum, ref, has_ref, stats = _oracle(
        spec, rt.tree, arena, rounds, deltas_seq, w_seq, pods)
    for b in range(rt.tree.num_boundaries):
        np.testing.assert_allclose(np.asarray(state.accum[b]), accum[b],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(state.ref[b]), ref[b])
        np.testing.assert_array_equal(np.asarray(state.has_ref[b]),
                                      has_ref[b])
    np.testing.assert_array_equal(np.asarray(state.syncs),
                                  stats["syncs"].astype(np.int32))
    np.testing.assert_allclose(np.asarray(state.accepts), stats["accepts"])
    np.testing.assert_allclose(np.asarray(state.vetoes), stats["vetoes"])
    # link accounting: payload per accepted pod, beacon per vetoed pod
    for b, link in enumerate(rt.links):
        want = (stats["accepts"][b] * link.payload_bytes
                + stats["vetoes"][b] * link.beacon_bytes)
        np.testing.assert_allclose(np.asarray(state.tier_bytes)[b], want,
                                   rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(state.tier_time)[b],
            stats["syncs"][b] * link.sync_time(), rtol=1e-6)
    assert rt.links[0].payload_bytes == arena.n * PARAM_BYTES


def test_bootstrap_accepts_all_then_theta_vetoes():
    # round 0: no reference yet -> every valid child accepted; once a
    # reference exists, an anti-aligned pod is vetoed
    arena = _arena()
    spec = TopologySpec(tiers=(TierSpec("leaf", fanout=4),
                               TierSpec("top", sync_every=1, theta=0.9)))
    rt = TopologyRuntime(spec, 8, arena)
    state = rt.init()
    pods = np.asarray(rt.pod_of)
    d = np.ones((8, arena.rows, arena.lane), np.float32)
    d *= np.asarray(arena.valid_mask())[None]
    d[pods == 1] *= -1.0                  # pod 1 moves opposite pod 0
    w = jnp.ones((8,), jnp.float32)
    state = rt.step(state, jnp.int32(0), jnp.asarray(d), w)
    assert int(state.syncs[0]) == 1
    assert float(state.accepts[0]) == 2.0       # bootstrap: both accepted
    state = rt.step(state, jnp.int32(1), jnp.asarray(d), w)
    # reference now = sign(mean) which cancels to 0 on conflicting pods;
    # re-run with aligned pods to pin the veto instead
    rt2 = TopologyRuntime(spec, 8, arena)
    s2 = rt2.init()
    d2 = np.ones((8, arena.rows, arena.lane), np.float32)
    d2 *= np.asarray(arena.valid_mask())[None]
    s2 = rt2.step(s2, jnp.int32(0), jnp.asarray(d2), w)     # ref := +1
    d3 = d2.copy()
    d3[pods == 1] *= -1.0                 # pod 1 now anti-aligned
    s2 = rt2.step(s2, jnp.int32(1), jnp.asarray(d3), w)
    assert float(s2.accepts[0]) == 3.0    # 2 (bootstrap) + 1 accepted
    assert float(s2.vetoes[0]) == 1.0     # pod 1 vetoed by theta
    # all-vetoed fallback keeps liveness: flip EVERY pod
    d4 = -d2
    s3 = rt2.step(s2, jnp.int32(2), jnp.asarray(d4), w)
    assert float(s3.accepts[0]) == 5.0    # fallback accepted both


def test_empty_topology_is_scan_safe():
    e = empty_topology()
    leaves = jax.tree.leaves(e)
    assert all(l.shape[0] == 0 for l in leaves)


# ---------------------------------------------------------------------------
# engine-level: measurement-only, path parity, checkpoint, single-tier
# ---------------------------------------------------------------------------

def test_topology_matrix_cell():
    spec = harness.base_spec(rounds=4, num_clients=8, theta=None)
    # theta-free tiers: veto decisions can fp-flip between vmap and
    # scan reduction orders, counts here must be exactly comparable
    topo = TopologySpec(tiers=(
        TierSpec("edge", fanout=3),
        TierSpec("region", fanout=2, sync_every=2),
        TierSpec("global", sync_every=4)))
    summaries = harness.assert_topology_parity(spec, topology=topo)
    assert all(s["syncs"] == [2, 1] for s in summaries.values())


def test_single_tier_is_todays_path():
    # a 1-tier tree resolves to no topology at the spec boundary, so
    # the engine literally runs today's code — records bit-equal
    spec = harness.base_spec(rounds=3, num_clients=5)
    one = dataclasses.replace(
        spec, topology=TopologySpec(tiers=(TierSpec("all"),)))
    assert one.validate().resolve_topology() is None
    a = harness.run_cell(spec, "megastep")
    b = harness.run_cell(one, "megastep")
    for ra, rb in zip(a.records, b.records):
        assert dataclasses.asdict(ra) == dataclasses.asdict(rb)


def test_checkpoint_restore_mid_run_bit_identical(tmp_path):
    spec = dataclasses.replace(
        harness.base_spec(rounds=8, num_clients=8),
        topology="two-tier-pods", megastep=True, rounds_per_dispatch=4)
    full = ExperimentSession.open(spec)
    full.run(8)
    part = ExperimentSession.open(spec)
    part.run(4)
    p = str(tmp_path / "topo.ckpt")
    part.checkpoint(p)
    resumed = ExperimentSession.restore(p)
    resumed.run(4)
    fa = jax.tree.leaves(full._driver.sim._topo_state)
    fb = jax.tree.leaves(resumed._driver.sim._topo_state)
    assert all(bool(jnp.array_equal(x, y)) for x, y in zip(fa, fb))
    np.testing.assert_array_equal(
        np.asarray(full._driver.sim._params_mat),
        np.asarray(resumed._driver.sim._params_mat))
    for ra, rb in zip(full.records, resumed.records):
        assert ra.bytes_sent == rb.bytes_sent
        assert ra.updates_applied == rb.updates_applied


def test_checkpoint_topology_mismatch_rejected(tmp_path):
    spec = dataclasses.replace(harness.base_spec(rounds=2, num_clients=5),
                               topology="two-tier-pods")
    s = ExperimentSession.open(spec)
    s.run(2)
    p = str(tmp_path / "t.ckpt")
    s.checkpoint(p)
    bare = dataclasses.replace(spec, topology=None)
    from repro.api import CheckpointMismatchError
    with pytest.raises(CheckpointMismatchError):
        ExperimentSession.restore(p, spec=bare)


def test_topology_summary_reports_reduction():
    spec = dataclasses.replace(harness.base_spec(rounds=4, num_clients=8),
                               topology="two-tier-pods")
    sess = ExperimentSession.open(spec)
    sess.run(4)
    summary = sess._driver.sim.topology_summary()
    assert summary["syncs"] == [1]              # sync_every=4, 4 rounds
    assert summary["total_bytes"] > 0
    assert summary["flat_star_bytes"] > summary["total_bytes"]
    assert 0.0 < summary["reduction"] <= 1.0
