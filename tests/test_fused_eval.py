"""Whole-experiment fusion: eval-in-carry parity, donated spmd steps,
and the vectorized multi-seed scanned path.

The fused scanned engine folds evaluation into the ``lax.scan`` carry
(``ExperimentSpec.fused_eval``), so a run's dispatch stream never
breaks for a host eval readback. These tests pin

  * the full harness parity cell (fused ≡ post-hoc ≡ loop, grouping-
    and checkpoint-invariant) — tests/harness.py owns the asserts;
  * spec validation: fused_eval composes only with the scanned sim
    engine and the default (traceable) eval;
  * the donation contract of the compiled spmd step: the driver NEVER
    touches a state it has already passed into the step (emulated
    donation — the previous state's buffers are deleted after every
    step, so any reuse raises), and donate=True produces the same
    trajectory as donate=False;
  * run_scanned_seed_batch: S seeds as one vmapped dispatch stream
    match S solo fused runs within the established vmap-vs-solo
    reduction tolerance (tests/test_sweep.py contract), and seeds that
    resolve different scanned trace shapes fail loudly.
"""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

import harness
from repro.api import (DataSpec, ExperimentSession, ExperimentSpec,
                       ROUND_FIELDS, SpecError, WorldSpec,
                       run_experiment, run_scanned_seed_batch)


def _fused_cell(rounds=6, eval_every=2, **kw):
    return dataclasses.replace(
        harness.base_spec(rounds=rounds, theta=None, **kw),
        eval_every=eval_every)


# ---------------------------------------------------------------------------
# eval-in-carry parity (satellite: harness cell)
# ---------------------------------------------------------------------------

def test_fused_eval_parity_cell(tmp_path):
    harness.assert_fused_equivalent(_fused_cell(), tmpdir=str(tmp_path))


def test_fused_grouping_invariance_with_theta():
    # θ decisions ride the carry too — grouping must stay invisible
    spec = dataclasses.replace(harness.base_spec(rounds=6, theta=0.6),
                               eval_every=2, megastep=True,
                               fused_eval=True)
    f1 = run_experiment(dataclasses.replace(spec, rounds_per_dispatch=1))
    f3 = run_experiment(dataclasses.replace(spec, rounds_per_dispatch=3))
    for a, b in zip(f3.records, f1.records):
        for f in ROUND_FIELDS:
            assert getattr(a, f) == getattr(b, f)


def test_fused_dispatch_count():
    # 6 rounds at R=3: 2 scan dispatches, zero extra eval dispatches
    spec = dataclasses.replace(_fused_cell(rounds=6), megastep=True,
                               rounds_per_dispatch=3, fused_eval=True)
    sess = ExperimentSession.open(spec)
    sess.run(spec.rounds)
    assert sess._driver.sim.dispatches == 2
    posthoc = ExperimentSession.open(
        dataclasses.replace(spec, fused_eval=False))
    posthoc.run(spec.rounds)
    assert posthoc._driver.sim.dispatches > 2


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------

def test_fused_requires_rounds_per_dispatch():
    spec = dataclasses.replace(harness.base_spec(), fused_eval=True)
    with pytest.raises(SpecError, match="rounds_per_dispatch"):
        spec.validate()


def test_fused_rejects_spmd_engine():
    spec = dataclasses.replace(harness.base_spec(), engine="spmd",
                               fused_eval=True, rounds_per_dispatch=2,
                               megastep=True)
    with pytest.raises(SpecError, match="sim-engine"):
        spec.validate()


def test_fused_rejects_custom_eval_fn():
    spec = dataclasses.replace(harness.base_spec(), fused_eval=True,
                               megastep=True, rounds_per_dispatch=2,
                               eval_fn=lambda params, arrays: 0.0)
    with pytest.raises(SpecError, match="eval_fn"):
        spec.validate()


# ---------------------------------------------------------------------------
# spmd donation (satellite: runner donate=False bug)
# ---------------------------------------------------------------------------

def _spmd_spec(rounds=5):
    return harness.path_spec(harness.base_spec(rounds=rounds), "spmd")


def test_spmd_driver_never_reuses_donated_state(tmp_path):
    """Emulate donation on CPU: delete every buffer of the state that
    was just passed into the compiled step. If any driver code path
    (accounting, eval, checkpointing) still read the donated state, it
    would raise on the deleted buffer."""
    spec = _spmd_spec()
    sess = ExperimentSession.open(spec)
    driver = sess._driver
    orig_step = driver.step

    def donating_step(state, batch):
        out = orig_step(state, batch)
        for leaf in jax.tree.leaves(state):
            if isinstance(leaf, jax.Array):
                leaf.delete()
        return out

    driver.step = donating_step
    sess.run(3)
    sess.checkpoint(str(tmp_path / "donated.ckpt"))   # post-step state live
    sess.run(spec.rounds - 3)
    res = sess.result()
    assert len(res.records) == spec.rounds
    ref = run_experiment(spec)
    for a, b in zip(res.records, ref.records):
        for f in ROUND_FIELDS:
            va, vb = getattr(a, f), getattr(b, f)
            if va != va and vb != vb:
                continue                 # NaN (unmeasured accuracy)
            assert va == vb


def test_spmd_donate_flag_is_trajectory_invariant():
    """The donate flag must not change the math — only buffer reuse.
    CPU ignores donation with a warning; silence it so the comparison
    runs everywhere."""
    from repro.core import fl_step

    spec = _spmd_spec(rounds=3)
    cfg = spec.resolve_model()
    rng = np.random.default_rng(0)
    batches = []
    for _ in range(3):
        batches.append({
            "x": np.asarray(rng.normal(
                size=(spec.world.num_clients, 32, cfg.num_features)),
                np.float32),
            "y": rng.integers(0, cfg.num_classes,
                              size=(spec.world.num_clients, 32)),
        })

    def run(donate):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            opt = None
            state = fl_step.init_state(jax.random.PRNGKey(spec.seed),
                                       cfg, opt)
            step = fl_step.build_fl_train_step(cfg, opt, donate=donate)
            traj = []
            for batch in batches:
                state, m = step(state, jax.tree.map(jax.numpy.asarray,
                                                    batch))
                traj.append(float(m["loss"]))
            return traj

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# vectorized multi-seed scanned path
# ---------------------------------------------------------------------------

def _batch_spec(rounds=5):
    return dataclasses.replace(
        ExperimentSpec(
            model="anomaly-mlp-smoke",
            data=DataSpec(n_samples=1200, eval_samples=300,
                          partition="iid"),
            world=WorldSpec(num_clients=5, profile="heterogeneous"),
            rounds=rounds, seed=0, rounds_per_dispatch=3,
            fused_eval=True),
        eval_every=2)


def test_scanned_seed_batch_matches_solo_runs():
    spec = _batch_spec()
    seeds = [0, 1, 2]
    batch = run_scanned_seed_batch(spec, seeds)
    for s, res in zip(seeds, batch):
        solo = run_experiment(dataclasses.replace(spec, seed=s))
        assert len(res.records) == len(solo.records) == spec.rounds
        for a, b in zip(res.records, solo.records):
            assert a.round == b.round
            assert a.updates_applied == b.updates_applied
            # the vmap-vs-solo reduction-order tolerance contract of
            # tests/test_sweep.py::test_seed_batch_matches_serial_runs
            np.testing.assert_allclose(a.sim_time, b.sim_time, rtol=1e-9)
            np.testing.assert_allclose(a.bytes_sent, b.bytes_sent,
                                       rtol=1e-9)
            np.testing.assert_allclose(a.accuracy, b.accuracy, atol=1e-5)
            np.testing.assert_allclose(a.loss, b.loss, rtol=1e-4)


def test_scanned_seed_batch_rejects_shape_mismatch():
    # dirichlet partitions are seed-dependent -> per-seed trace shapes
    # diverge; the batch path must refuse loudly, not silently pad math
    spec = dataclasses.replace(
        _batch_spec(), data=DataSpec(n_samples=1200, eval_samples=300,
                                     partition="dirichlet"))
    with pytest.raises(ValueError, match="trace shapes"):
        run_scanned_seed_batch(spec, [0, 1, 2])


def test_scanned_seed_batch_requires_scanned_engine():
    spec = dataclasses.replace(_batch_spec(), rounds_per_dispatch=None,
                               fused_eval=False)
    with pytest.raises(ValueError, match="rounds_per_dispatch"):
        run_scanned_seed_batch(spec, [0, 1])
