"""Production mesh-mapped FL step invariants (core/fl_step.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import anomaly_mlp
from repro.core import fl_step
from repro.optim import adamw as optim_mod

CFG = anomaly_mlp.CONFIG.replace(mlp_hidden=(16, 8), num_features=10,
                                 num_classes=3)


def _batch(C=4, B=8, seed=0):
    rng = np.random.default_rng(seed)
    return {"x": jnp.asarray(rng.normal(size=(C, B, CFG.num_features)),
                             jnp.float32),
            "y": jnp.asarray(rng.integers(0, CFG.num_classes, size=(C, B)))}


def test_theta_none_is_fedavg():
    """mask forced to ones must equal the no-filter baseline exactly."""
    opt = optim_mod.sgd(1e-2)
    s0 = fl_step.init_state(jax.random.PRNGKey(0), CFG, opt)
    step_f = fl_step.build_fl_train_step(CFG, opt, theta=None, donate=False)
    step_t = fl_step.build_fl_train_step(CFG, opt, theta=0.0, donate=False)
    b = _batch()
    s1, m1 = step_f(s0, b)
    s2, m2 = step_t(s0, b)
    for a, c in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-6)


def test_filtering_changes_aggregate_when_masked():
    opt = optim_mod.sgd(1e-2)
    s0 = fl_step.init_state(jax.random.PRNGKey(0), CFG, opt)
    step = fl_step.build_fl_train_step(CFG, opt, theta=0.65, donate=False)
    b = _batch()
    s1, m1 = step(s0, b)          # bootstrap round accepts all
    assert float(m1["accept_rate"]) == 1.0
    s2, m2 = step(s1, b)
    assert 0.0 <= float(m2["accept_rate"]) <= 1.0
    assert np.isfinite(float(m2["loss"]))
    # bytes metric: sent <= baseline
    assert float(m2["bytes_sent"]) <= float(m2["bytes_baseline"]) + 1e-6


def test_no_pass_fallback_keeps_training():
    """If no client passes theta, the fallback accepts all (no stall)."""
    opt = optim_mod.sgd(1e-2)
    s0 = fl_step.init_state(jax.random.PRNGKey(0), CFG, opt)
    step = fl_step.build_fl_train_step(CFG, opt, theta=1.01, donate=False)
    b = _batch()
    s1, _ = step(s0, b)
    s2, m2 = step(s1, b)
    assert float(m2["accept_rate"]) == 0.0       # nobody passes theta>1
    moved = any(not np.allclose(np.asarray(x), np.asarray(y))
                for x, y in zip(jax.tree.leaves(s1.params),
                                jax.tree.leaves(s2.params)))
    assert moved, "fallback must keep the global model moving"


def test_loss_decreases_over_rounds():
    opt = optim_mod.sgd(5e-2)
    s = fl_step.init_state(jax.random.PRNGKey(0), CFG, opt)
    step = fl_step.build_fl_train_step(CFG, opt, theta=0.55, donate=False)
    losses = []
    for i in range(15):
        s, m = step(s, _batch(seed=i % 3))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_ref_sign_updates():
    opt = optim_mod.sgd(1e-2)
    s0 = fl_step.init_state(jax.random.PRNGKey(0), CFG, opt)
    assert all(int(jnp.abs(l).max()) == 0
               for l in jax.tree.leaves(s0.ref_sign))
    step = fl_step.build_fl_train_step(CFG, opt, theta=0.65, donate=False)
    s1, _ = step(s0, _batch())
    nonzero = sum(int(jnp.abs(l).sum()) for l in jax.tree.leaves(s1.ref_sign))
    assert nonzero > 0
