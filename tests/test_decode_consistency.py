"""Strong correctness: single-token decode against a prefix cache must
reproduce the full-sequence forward logits (fp32 smoke configs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import api

# transformer-family exact-cache archs + state-based archs
ARCHS = ["qwen2-1.5b", "phi3-mini-3.8b", "stablelm-1.6b", "granite-34b",
         "granite-moe-1b-a400m", "rwkv6-7b", "hymba-1.5b", "whisper-tiny",
         "internvl2-2b", "arctic-480b"]


def _fp32(cfg):
    cfg = cfg.replace(dtype="float32")
    if cfg.num_experts:
        # capacity-based MoE drops depend on batch context; disable drops so
        # prefill and decode route identically (pure consistency check)
        cfg = cfg.replace(capacity_factor=100.0)
    return cfg


def _inputs(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    toks = S - (cfg.num_patches if cfg.family == "vlm" else 0)
    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, size=(B, toks)))}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, cfg.d_model)).astype(
                np.float32))
    if cfg.family == "audio":
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)).astype(
                np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    cfg = _fp32(registry.get_config(arch, smoke=True))
    B, S = 2, 12
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    full = _inputs(cfg, B, S)

    # full-sequence logits
    logits_full, cache_full = api.prefill(params, full, cfg)

    # prefill on S-1 tokens, then decode token S-1
    prefix = dict(full)
    prefix["tokens"] = full["tokens"][:, :-1]
    _, cache = api.prefill(params, prefix, cfg)

    # grow KV caches to length S where needed (transformer/whisper k,v)
    grown = api.init_cache(cfg, B, S + (cfg.num_patches
                                        if cfg.family == "vlm" else 0))
    def graft(dst, src):
        if dst.ndim == src.ndim and dst.shape != src.shape:
            return jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), (0,) * dst.ndim)
        return src
    cache = jax.tree.map(graft, grown, cache)
    cache["step"] = jnp.asarray(
        full["tokens"].shape[1] - 1
        + (cfg.num_patches if cfg.family == "vlm" else 0), jnp.int32)

    last = {"tokens": full["tokens"][:, -1:]}
    logits_step, _ = api.decode_step(params, cache, last, cfg)

    want = np.asarray(logits_full[:, -1], np.float32)
    got = np.asarray(logits_step[:, 0], np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
